//! `d4m` — leader entrypoint / CLI for the D4M reproduction.
//!
//! Subcommands:
//!
//! * `d4m info` — artifact manifest + PJRT platform report.
//! * `d4m demo` — build and print the paper's Figure-1 array, run the
//!   basic algebra on it.
//! * `d4m ingest [--triples N] [--workers W] [--policy hash|range]
//!   [--latency-us L]` — run the sharded ingest pipeline against an
//!   in-process table store and report throughput/backpressure.
//! * `d4m op --op <constructor|add|matmul|elemmul> [--n N]` — time one
//!   paper operation at scale `n` on the d4m engine.
//!
//! The figure reproductions live in `cargo bench` targets (one per
//! paper figure); the end-to-end driver is `examples/ingest_pipeline`.

use d4m::assoc::Assoc;
use d4m::bench::Workload;
use d4m::pipeline::{IngestPipeline, PipelineConfig, ShardPolicy};
use d4m::store::{Table, TableConfig, Triple};
use d4m::util::{human, time_op, Args};
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(),
        "demo" => demo(),
        "ingest" => ingest(&args),
        "op" => op(&args),
        _ => {
            eprintln!(
                "usage: d4m <info|demo|ingest|op> [flags]\n\
                 \n  info    — artifact manifest + PJRT platform\
                 \n  demo    — the paper's Figure 1 walkthrough\
                 \n  ingest  — sharded pipeline ingest (--triples --workers --policy --latency-us)\
                 \n  op      — time one op (--op constructor|add|matmul|elemmul, --n N)"
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

fn info() {
    match d4m::runtime::Runtime::load_default() {
        Ok(rt) => {
            println!("PJRT runtime loaded from artifacts/:");
            for a in rt.artifacts() {
                println!(
                    "  {:28} kind={:7} semiring={:11} tile={}x{} block={} inputs={}",
                    a.name, a.kind, a.semiring, a.size, a.size, a.block, a.num_inputs
                );
            }
        }
        Err(e) => println!("runtime unavailable ({e}); run `make artifacts`"),
    }
}

fn demo() {
    let a = Assoc::from_triples(
        &["0294.mp3", "0294.mp3", "0294.mp3", "1829.mp3", "1829.mp3", "1829.mp3", "7802.mp3",
            "7802.mp3", "7802.mp3"],
        &["artist", "duration", "genre", "artist", "duration", "genre", "artist", "duration",
            "genre"],
        &["Pink Floyd", "6:53", "rock", "Samuel Barber", "8:01", "classical", "Taylor Swift",
            "10:12", "pop"][..],
    );
    println!("A =\n{a}");
    println!("A row keys: {:?}", a.row_keys().iter().map(|k| k.to_string()).collect::<Vec<_>>());
    println!("AᵀA (track-attribute correlation) =\n{}", a.sqin());
    println!("genre column:\n{}", a.get_col("genre"));
}

fn ingest(args: &Args) {
    let triples = args.usize_or("triples", 1_000_000);
    let workers = args.usize_or("workers", 4);
    let latency = args.usize_or("latency-us", 0) as u64;
    let policy = match args.str_or("policy", "hash").as_str() {
        "range" => ShardPolicy::Range { splits: vec![] },
        _ => ShardPolicy::Hash,
    };
    let table = Arc::new(Table::new(
        "ingest",
        TableConfig { split_threshold: 8 << 20, write_latency_us: latency },
    ));
    let mut p = IngestPipeline::start(
        Arc::clone(&table),
        PipelineConfig { workers, policy, ..Default::default() },
    );
    let mut r = d4m::util::SplitMix64::new(7);
    for i in 0..triples {
        p.submit(Triple::new(
            format!("r{:012}", r.next_u64() % (triples as u64)),
            format!("c{}", i % 64),
            "1",
        ));
    }
    let report = p.finish();
    println!(
        "ingested {} triples in {} ({}), {} workers, {} stalls, imbalance {:.2}, {} tablets",
        human::count(report.written as u64),
        human::seconds(report.elapsed_s),
        human::rate(report.rate()),
        report.per_worker.len(),
        report.stalls,
        report.imbalance(),
        table.tablet_count(),
    );
}

fn op(args: &Args) {
    let n = args.usize_or("n", 12);
    let opname = args.str_or("op", "matmul");
    let w = Workload::generate(n, 20220910);
    let ones = w.ones();
    let a = Assoc::from_triples(&w.rows, &w.cols, d4m::assoc::ValsInput::Num(ones.clone()));
    let b = Assoc::from_triples(&w.rows2, &w.cols2, d4m::assoc::ValsInput::Num(ones.clone()));
    let timings = match opname.as_str() {
        "constructor" => time_op(1, 10, |_| {
            Assoc::from_triples(&w.rows, &w.cols, d4m::assoc::ValsInput::Num(w.num_vals.clone()))
        }),
        "add" => time_op(1, 10, |_| a.add(&b)),
        "matmul" => time_op(1, 10, |_| a.matmul(&b)),
        "elemmul" => time_op(1, 10, |_| a.elemmul(&b)),
        other => {
            eprintln!("unknown --op {other}");
            std::process::exit(2);
        }
    };
    println!(
        "{opname} @ n={n} ({} triples): mean {} median {} min {}",
        human::count(Workload::len_for(n) as u64),
        human::seconds(timings.mean_s()),
        human::seconds(timings.median_s()),
        human::seconds(timings.min_s()),
    );
}
