//! Benchmark support: the paper's workload generators (§III.A) and the
//! harness that prints/persists each figure's series.
//!
//! The paper's setup: for each `5 ≤ n ≤ 18`, six arrays of `8·2^n`
//! elements — `rows`, `rows2`, `cols`, `cols2` are uniform random
//! integers in `[0, 2^n]` *cast to strings*; `num_vals` are uniform
//! integers in `[1, 100]`; `string_vals` are uniform random strings of
//! length 8. (The paper says "between 0 and 100"; zero-valued entries
//! would be unstored, so the generator uses `[1, 100]` to keep every
//! triple live — the keys, counts and collision structure are
//! unchanged.) Runs are averaged over 10 repeats on one core.

pub mod workload;

pub use workload::Workload;

use crate::util::human;
use crate::util::timer::Timings;
use crate::util::Json;
use std::io::Write;

/// One measured point of a figure series.
#[derive(Debug, Clone)]
pub struct Point {
    /// Problem scale exponent (array is ~2ⁿ × 2ⁿ).
    pub n: usize,
    /// Engine / series label.
    pub series: String,
    /// Timing statistics.
    pub timings: Timings,
    /// Output nnz (work witness; also cross-checks engines).
    pub out_nnz: usize,
}

/// Collector that prints the figure's table as it runs and writes a CSV
/// at the end — one file per reproduced figure.
pub struct FigureHarness {
    /// Figure id, e.g. `"fig3"`.
    pub id: String,
    /// Human title, e.g. `"Assoc constructor (numeric values)"`.
    pub title: String,
    points: Vec<Point>,
}

impl FigureHarness {
    /// Start a figure run (prints the header).
    pub fn new(id: &str, title: &str) -> Self {
        println!("== {id}: {title} ==");
        println!(
            "{:>4} {:>12} {:>12} {:>12} {:>12} {:>10}",
            "n", "engine", "mean", "median", "min", "out_nnz"
        );
        FigureHarness { id: id.to_string(), title: title.to_string(), points: Vec::new() }
    }

    /// Record (and print) one measurement.
    pub fn record(&mut self, n: usize, series: &str, timings: Timings, out_nnz: usize) {
        println!(
            "{:>4} {:>12} {:>12} {:>12} {:>12} {:>10}",
            n,
            series,
            human::seconds(timings.mean_s()),
            human::seconds(timings.median_s()),
            human::seconds(timings.min_s()),
            out_nnz,
        );
        self.points.push(Point { n, series: series.to_string(), timings, out_nnz });
    }

    /// Write `results/<id>.csv` with one row per point.
    pub fn write_csv(&self, dir: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!("{}.csv", self.id));
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(f, "figure,n,engine,mean_s,median_s,min_s,stddev_s,out_nnz")?;
        for p in &self.points {
            writeln!(
                f,
                "{},{},{},{:.9},{:.9},{:.9},{:.9},{}",
                self.id,
                p.n,
                p.series,
                p.timings.mean_s(),
                p.timings.median_s(),
                p.timings.min_s(),
                p.timings.stddev_s(),
                p.out_nnz
            )?;
        }
        f.flush()?;
        println!("[{}] wrote {}", self.id, path.display());
        Ok(path)
    }

    /// Recorded points (for shape assertions in tests).
    pub fn points(&self) -> &[Point] {
        &self.points
    }
}

/// One machine-readable performance record — the unit of the repo's
/// perf-trajectory files (`BENCH_PR2.json`, …), consumed by
/// `scripts/summarize_results.py` and archived as a CI artifact.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Operation label, e.g. `"hypersparse-matmul-adaptive"`.
    pub op: String,
    /// Problem scale exponent (workload is ~2ⁿ-sized).
    pub scale: usize,
    /// Worker count the measurement ran at.
    pub threads: usize,
    /// Mean wall-clock per operation, in nanoseconds.
    pub ns_per_op: f64,
    /// Speedup vs the record's baseline (the baseline itself records
    /// `1.0`; see each bench's printed legend for what it compares).
    pub speedup: f64,
    /// Extra labeled metrics rendered as additional JSON fields —
    /// e.g. the SpGEMM accumulator-policy row counters
    /// (`rows_copy`/`rows_sort`/`rows_hash`/`rows_dense`), flop counts,
    /// or output sizes. Additive within schema `d4m-bench-v1`.
    pub extras: Vec<(String, f64)>,
}

impl BenchRecord {
    /// A record with no extra metrics.
    pub fn new(op: &str, scale: usize, threads: usize, ns_per_op: f64, speedup: f64) -> Self {
        BenchRecord { op: op.to_string(), scale, threads, ns_per_op, speedup, extras: Vec::new() }
    }

    /// Attach one extra labeled metric (builder style).
    pub fn with_extra(mut self, key: &str, value: f64) -> Self {
        self.extras.push((key.to_string(), value));
        self
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("op".into(), Json::str(&self.op)),
            ("scale".into(), Json::Num(self.scale as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("ns_per_op".into(), Json::Num(self.ns_per_op)),
            ("speedup".into(), Json::Num(self.speedup)),
        ];
        for (k, v) in &self.extras {
            fields.push((k.clone(), Json::Num(*v)));
        }
        Json::Obj(fields)
    }
}

/// Write `<dir>/<name>` as `{"schema": "d4m-bench-v1", "records":
/// [...]}` — the machine-readable companion to the figure CSVs.
pub fn write_bench_json(
    dir: &str,
    name: &str,
    records: &[BenchRecord],
) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = std::path::Path::new(dir).join(name);
    let doc = Json::Obj(vec![
        ("schema".into(), Json::str("d4m-bench-v1")),
        ("records".into(), Json::Arr(records.iter().map(BenchRecord::to_json).collect())),
    ]);
    std::fs::write(&path, doc.render() + "\n")?;
    println!("[bench] wrote {}", path.display());
    Ok(path)
}

/// Standard bench CLI: `--min-n`, `--max-n`, `--repeats`, `--full`,
/// `--out <dir>`, `--threads <N>`. `--full` runs the paper's full
/// range; the default is a reduced sweep so `cargo bench` completes
/// quickly. `--threads` sets the process-default [`Parallelism`] for
/// the d4m engine; **absent means 1 (the exact serial code paths)** so
/// the figure CSVs stay comparable with the serial baselines and with
/// historical captures — pass `--threads N` to opt into parallel
/// measurement at a fixed worker count.
///
/// [`Parallelism`]: crate::util::Parallelism
pub struct BenchParams {
    /// Smallest n.
    pub min_n: usize,
    /// Largest n (inclusive).
    pub max_n: usize,
    /// Timed repeats per point (paper: 10).
    pub repeats: usize,
    /// Output directory for CSVs.
    pub out_dir: String,
    /// Optional worker-count override (`--threads N`; `None` = serial,
    /// i.e. [`BenchParams::apply_parallelism`] pins `threads = 1`).
    pub threads: Option<usize>,
}

impl BenchParams {
    /// Parse from argv with figure-appropriate defaults. `paper_max_n`
    /// is the figure's full-range cap (18 for Figs 3–5, 17 for Fig 6,
    /// 13 for Fig 7); the quick default sweeps to `quick_max_n`.
    pub fn from_env(paper_max_n: usize, quick_max_n: usize) -> Self {
        let args = crate::util::Args::from_env();
        let full = args.flag("full");
        let default_max = if full { paper_max_n } else { quick_max_n.min(paper_max_n) };
        let default_reps = if full { 10 } else { 3 };
        BenchParams {
            min_n: args.usize_or("min-n", 5),
            max_n: args.usize_or("max-n", default_max),
            repeats: args.usize_or("repeats", default_reps),
            out_dir: args.str_or("out", "results"),
            threads: match args.usize_or("threads", 0) {
                0 => None,
                n => Some(n),
            },
        }
    }

    /// Install `--threads` as the process-default
    /// [`crate::util::Parallelism`] — call once at bench start. Without
    /// the flag, a `D4M_THREADS` environment variable applies; with
    /// neither, the benches pin the serial code paths (`threads = 1`),
    /// keeping the engine comparison and historical CSVs meaningful.
    pub fn apply_parallelism(&self) {
        let threads =
            self.threads.or_else(crate::util::Parallelism::env_threads).unwrap_or(1);
        crate::util::Parallelism::with_threads(threads).set_default();
    }

    /// The swept n values.
    pub fn ns(&self) -> impl Iterator<Item = usize> {
        self.min_n..=self.max_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn harness_collects_and_writes_csv() {
        let mut h = FigureHarness::new("figtest", "test figure");
        h.record(5, "d4m-rs", Timings { samples: vec![Duration::from_millis(1)] }, 42);
        h.record(5, "hashmap", Timings { samples: vec![Duration::from_millis(2)] }, 42);
        let dir = std::env::temp_dir().join("d4m-bench-test");
        let path = h.write_csv(dir.to_str().unwrap()).unwrap();
        let content = std::fs::read_to_string(path).unwrap();
        assert!(content.starts_with("figure,n,engine"));
        assert_eq!(content.lines().count(), 3);
        assert!(content.contains("figtest,5,d4m-rs"));
        assert_eq!(h.points().len(), 2);
    }

    #[test]
    fn bench_json_has_schema_and_fields() {
        let recs = vec![BenchRecord::new("hypersparse-matmul-adaptive", 14, 4, 1234.5, 1.75)
            .with_extra("rows_copy", 4096.0)];
        let dir = std::env::temp_dir().join("d4m-bench-json-test");
        let path = write_bench_json(dir.to_str().unwrap(), "BENCH_TEST.json", &recs).unwrap();
        let content = std::fs::read_to_string(path).unwrap();
        assert!(content.contains("\"schema\":\"d4m-bench-v1\""));
        assert!(content.contains("\"op\":\"hypersparse-matmul-adaptive\""));
        assert!(content.contains("\"scale\":14"));
        assert!(content.contains("\"threads\":4"));
        assert!(content.contains("\"speedup\":1.75"));
        assert!(content.contains("\"rows_copy\":4096"));
    }
}
