//! The paper's §III.A workload generator.

use crate::util::SplitMix64;

/// The six §III.A input arrays for one scale `n`, generated
/// deterministically (seeded) instead of loaded from the paper's
/// `rows.txt` … `string_vals.txt` files.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Scale exponent: keys in `[0, 2ⁿ]`, `8·2ⁿ` triples.
    pub n: usize,
    /// Row keys for operand A (`rows.txt[n]`).
    pub rows: Vec<String>,
    /// Column keys for operand A (`cols.txt[n]`).
    pub cols: Vec<String>,
    /// Row keys for operand B (`rows2.txt[n]`).
    pub rows2: Vec<String>,
    /// Column keys for operand B (`cols2.txt[n]`).
    pub cols2: Vec<String>,
    /// Numeric values (`num_vals.txt[n]`, uniform in `[1, 100]`).
    pub num_vals: Vec<f64>,
    /// String values (`string_vals.txt[n]`, random length-8 strings).
    pub str_vals: Vec<String>,
}

impl Workload {
    /// Number of triples at scale `n` (the paper's `8 · 2ⁿ`).
    pub fn len_for(n: usize) -> usize {
        8usize << n
    }

    /// Generate the full workload for scale `n` with a fixed seed
    /// (distinct streams per array, all derived from `seed`).
    pub fn generate(n: usize, seed: u64) -> Workload {
        let len = Self::len_for(n);
        let universe = (1u64 << n) + 1; // "between 0 and 2^n" inclusive
        let mut root = SplitMix64::new(seed ^ (n as u64) << 32);
        let key_stream = |r: &mut SplitMix64| -> Vec<String> {
            (0..len).map(|_| r.below(universe).to_string()).collect()
        };
        let mut r1 = root.split();
        let mut r2 = root.split();
        let mut r3 = root.split();
        let mut r4 = root.split();
        let mut r5 = root.split();
        let mut r6 = root.split();
        Workload {
            n,
            rows: key_stream(&mut r1),
            cols: key_stream(&mut r2),
            rows2: key_stream(&mut r3),
            cols2: key_stream(&mut r4),
            num_vals: (0..len).map(|_| r5.range_i64(1, 100) as f64).collect(),
            str_vals: (0..len).map(|_| r6.ascii_lower(8)).collect(),
        }
    }

    /// The all-ones value vector used by the add/matmul/elemmul benches
    /// (`Assoc(rows, cols, 1)`).
    pub fn ones(&self) -> Vec<f64> {
        vec![1.0; self.rows.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_paper() {
        let w = Workload::generate(5, 1);
        assert_eq!(w.rows.len(), 8 * 32);
        assert_eq!(w.cols.len(), w.rows.len());
        assert_eq!(w.num_vals.len(), w.rows.len());
        assert_eq!(w.str_vals.len(), w.rows.len());
    }

    #[test]
    fn keys_in_range_and_stringy() {
        let w = Workload::generate(6, 2);
        for k in w.rows.iter().chain(&w.cols).chain(&w.rows2).chain(&w.cols2) {
            let v: u64 = k.parse().expect("integer-as-string key");
            assert!(v <= 64, "key {v} exceeds 2^6");
        }
    }

    #[test]
    fn values_in_declared_ranges() {
        let w = Workload::generate(7, 3);
        assert!(w.num_vals.iter().all(|&v| (1.0..=100.0).contains(&v) && v.fract() == 0.0));
        assert!(w.str_vals.iter().all(|s| s.len() == 8));
    }

    #[test]
    fn deterministic_per_seed_and_distinct_per_stream() {
        let a = Workload::generate(5, 42);
        let b = Workload::generate(5, 42);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.str_vals, b.str_vals);
        let c = Workload::generate(5, 43);
        assert_ne!(a.rows, c.rows);
        // Streams differ from each other.
        assert_ne!(a.rows, a.rows2);
        assert_ne!(a.cols, a.cols2);
    }

    #[test]
    fn collision_rate_is_papers() {
        // ~8 entries per row over a 2^n key space: with 8·2^n draws over
        // (2^n)² cells the collision rate is low but nonzero.
        let w = Workload::generate(8, 7);
        use std::collections::HashSet;
        let pairs: HashSet<(String, String)> = w
            .rows
            .iter()
            .cloned()
            .zip(w.cols.iter().cloned())
            .collect();
        let unique = pairs.len();
        let total = w.rows.len();
        assert!(unique <= total);
        assert!(unique as f64 > 0.9 * total as f64, "too many collisions: {unique}/{total}");
    }
}
