//! Streaming ingest pipeline — the L3 coordination layer.
//!
//! D4M's headline deployments are high-rate triple ingest into the
//! distributed store (the 100M-inserts/s Accumulo result in the paper's
//! lineage). This module is that orchestrator, scaled to one process:
//!
//! ```text
//!   source ──► sharder ──bounded queues──► worker 0 ─BatchWriter─► Table
//!                 │                        worker 1 ─BatchWriter─►  (tablets)
//!                 └── backpressure: send blocks when a queue is full
//! ```
//!
//! * **Sharding** — triples are routed to workers by hash or by row
//!   range ([`ShardPolicy`]); range sharding aligns workers with tablet
//!   split points so writers rarely cross-lock tablets.
//! * **Backpressure** — queues are bounded `sync_channel`s: when
//!   workers fall behind, the producer blocks instead of buffering
//!   without limit. Queue-full stalls are counted in [`IngestReport`].
//! * **Rebalancing** — [`IngestPipeline::rebalance_splits`] re-derives
//!   range boundaries from a key sample (used between ingest waves).

mod shard;

pub use shard::{sample_split_points, ShardPolicy, Sharder};

use crate::store::{BatchWriter, Table, Triple, WriterConfig};
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Pipeline tuning.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Number of ingest worker threads.
    pub workers: usize,
    /// Bound of each worker's queue, in triples (the backpressure knob).
    pub queue_depth: usize,
    /// Batch-writer settings used by every worker.
    pub writer: WriterConfig,
    /// Shard-routing policy.
    pub policy: ShardPolicy,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            workers: 4,
            queue_depth: 1024,
            writer: WriterConfig::default(),
            policy: ShardPolicy::Hash,
        }
    }
}

/// Outcome of one ingest run.
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Triples submitted by the producer.
    pub submitted: usize,
    /// Triples written to the table (== submitted on success).
    pub written: usize,
    /// Times the producer blocked on a full queue (backpressure events).
    pub stalls: usize,
    /// Wall-clock seconds for the whole run.
    pub elapsed_s: f64,
    /// Per-worker triple counts (shard balance diagnostic).
    pub per_worker: Vec<usize>,
    /// Batch flushes across workers.
    pub flushes: usize,
}

impl IngestReport {
    /// Ingest rate in triples/second.
    pub fn rate(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.written as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Shard imbalance: max/mean worker load (1.0 = perfectly even).
    pub fn imbalance(&self) -> f64 {
        let max = self.per_worker.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.written as f64 / self.per_worker.len().max(1) as f64;
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    }
}

/// A running ingest pipeline bound to one destination table.
pub struct IngestPipeline {
    senders: Vec<SyncSender<Vec<Triple>>>,
    workers: Vec<JoinHandle<(usize, usize)>>,
    sharder: Sharder,
    stalls: usize,
    submitted: usize,
    started: Instant,
    /// Micro-batch assembly buffers, one per worker.
    pending: Vec<Vec<Triple>>,
    micro_batch: usize,
}

impl IngestPipeline {
    /// Spawn workers and return a ready pipeline writing into `table`.
    pub fn start(table: Arc<Table>, config: PipelineConfig) -> Self {
        assert!(config.workers >= 1);
        let live_counter = Arc::new(AtomicUsize::new(0));
        let mut senders = Vec::with_capacity(config.workers);
        let mut workers = Vec::with_capacity(config.workers);
        for w in 0..config.workers {
            let (tx, rx): (SyncSender<Vec<Triple>>, Receiver<Vec<Triple>>) =
                sync_channel(config.queue_depth);
            let table = Arc::clone(&table);
            let wconf = config.writer.clone();
            let _live = Arc::clone(&live_counter);
            let handle = std::thread::Builder::new()
                .name(format!("d4m-ingest-{w}"))
                .spawn(move || {
                    let mut writer = BatchWriter::new(table, wconf);
                    let mut count = 0usize;
                    while let Ok(batch) = rx.recv() {
                        count += batch.len();
                        writer.put_all(batch);
                    }
                    writer.flush().expect("ingest worker flush");
                    (count, writer.flushes)
                })
                .expect("spawn ingest worker");
            senders.push(tx);
            workers.push(handle);
        }
        let sharder = Sharder::new(config.policy, config.workers);
        IngestPipeline {
            senders,
            workers,
            sharder,
            stalls: 0,
            submitted: 0,
            started: Instant::now(),
            pending: (0..config.workers).map(|_| Vec::new()).collect(),
            micro_batch: 64,
        }
    }

    /// Submit one triple. Blocks (backpressure) when the destination
    /// worker's queue is full; the stall is counted.
    pub fn submit(&mut self, t: Triple) {
        let w = self.sharder.route(&t.row);
        self.submitted += 1;
        self.pending[w].push(t);
        if self.pending[w].len() >= self.micro_batch {
            self.dispatch(w);
        }
    }

    /// Submit many triples.
    pub fn submit_all(&mut self, ts: impl IntoIterator<Item = Triple>) {
        for t in ts {
            self.submit(t);
        }
    }

    fn dispatch(&mut self, w: usize) {
        let batch = std::mem::take(&mut self.pending[w]);
        match self.senders[w].try_send(batch) {
            Ok(()) => {}
            Err(TrySendError::Full(batch)) => {
                // Backpressure: block until the worker drains.
                self.stalls += 1;
                self.senders[w].send(batch).expect("worker alive");
            }
            Err(TrySendError::Disconnected(_)) => panic!("ingest worker died"),
        }
    }

    /// Re-derive range-shard boundaries from the table's current split
    /// points (no-op under hash sharding). Call between ingest waves.
    pub fn rebalance_splits(&mut self, table: &Table) {
        self.sharder.rebalance(&table.split_points());
    }

    /// Flush all pending micro-batches, stop workers, and report.
    pub fn finish(mut self) -> IngestReport {
        for w in 0..self.pending.len() {
            if !self.pending[w].is_empty() {
                self.dispatch(w);
            }
        }
        // Close channels so workers drain and exit.
        drop(std::mem::take(&mut self.senders));
        let mut per_worker = Vec::new();
        let mut flushes = 0;
        for h in self.workers.drain(..) {
            let (count, f) = h.join().expect("ingest worker panicked");
            per_worker.push(count);
            flushes += f;
        }
        let written = per_worker.iter().sum();
        IngestReport {
            submitted: self.submitted,
            written,
            stalls: self.stalls,
            elapsed_s: self.started.elapsed().as_secs_f64(),
            per_worker,
            flushes,
        }
    }
}

impl Drop for IngestPipeline {
    fn drop(&mut self) {
        // Close channels; detach workers (finish() is the normal path).
        self.senders.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{ScanRange, TableConfig};

    fn mk_table(latency_us: u64) -> Arc<Table> {
        Arc::new(Table::new(
            "t",
            TableConfig { split_threshold: 1 << 16, write_latency_us: latency_us },
        ))
    }

    fn triples(n: usize) -> Vec<Triple> {
        (0..n).map(|i| Triple::new(format!("row{i:06}"), "c", "v")).collect()
    }

    #[test]
    fn ingests_everything_hash_sharded() {
        let table = mk_table(0);
        let mut p = IngestPipeline::start(
            Arc::clone(&table),
            PipelineConfig { workers: 3, ..Default::default() },
        );
        p.submit_all(triples(5000));
        let report = p.finish();
        assert_eq!(report.submitted, 5000);
        assert_eq!(report.written, 5000);
        assert_eq!(table.len(), 5000);
        assert_eq!(report.per_worker.len(), 3);
        assert!(report.per_worker.iter().all(|&c| c > 0), "all workers used");
        // Hash sharding should be reasonably balanced.
        assert!(report.imbalance() < 1.5, "imbalance {}", report.imbalance());
    }

    #[test]
    fn range_sharding_routes_by_split_points() {
        let table = mk_table(0);
        let mut p = IngestPipeline::start(
            Arc::clone(&table),
            PipelineConfig {
                workers: 2,
                policy: ShardPolicy::Range { splits: vec!["row005000".into()] },
                ..Default::default()
            },
        );
        p.submit_all(triples(10000));
        let report = p.finish();
        assert_eq!(report.written, 10000);
        // Split at the median → both workers hit.
        assert!(report.per_worker.iter().all(|&c| c == 5000), "{:?}", report.per_worker);
    }

    #[test]
    fn backpressure_stalls_counted_with_slow_store() {
        let table = mk_table(200); // 200µs per batch write — slow server
        let mut p = IngestPipeline::start(
            Arc::clone(&table),
            PipelineConfig {
                workers: 1,
                queue_depth: 1, // tiny queue to force stalls
                // Tiny write buffer so every micro-batch hits the slow
                // table instead of sitting in the BatchWriter.
                writer: WriterConfig { batch_bytes: 256, ..Default::default() },
                ..Default::default()
            },
        );
        p.submit_all(triples(2000));
        let report = p.finish();
        assert_eq!(report.written, 2000);
        assert!(report.stalls > 0, "expected backpressure stalls");
    }

    #[test]
    fn scan_after_ingest_is_sorted_and_complete() {
        let table = mk_table(0);
        let mut p = IngestPipeline::start(Arc::clone(&table), PipelineConfig::default());
        p.submit_all(triples(1000));
        p.finish();
        let all = table.scan(ScanRange::all());
        assert_eq!(all.len(), 1000);
        assert!(all.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn rebalance_from_table_splits() {
        let table = Arc::new(Table::new(
            "t",
            TableConfig { split_threshold: 1 << 10, write_latency_us: 0 },
        ));
        let mut p = IngestPipeline::start(
            Arc::clone(&table),
            PipelineConfig {
                workers: 2,
                policy: ShardPolicy::Range { splits: vec![] },
                ..Default::default()
            },
        );
        // Wave 1: all triples go to worker 0 (no splits yet).
        p.submit_all(triples(2000));
        p.rebalance_splits(&table);
        // Wave 2 distributes.
        p.submit_all(triples(2000));
        let report = p.finish();
        assert_eq!(report.written, 4000);
    }
}
