//! Shard routing: which worker owns a row key.

/// Routing policy for the ingest pipeline.
#[derive(Debug, Clone)]
pub enum ShardPolicy {
    /// FNV-1a hash of the row key modulo worker count. Uniform load,
    /// but a worker's writes touch arbitrary tablets.
    Hash,
    /// Range partitioning by explicit boundary keys (worker `i` owns
    /// keys in `[splits[i-1], splits[i])`). Aligns workers with tablet
    /// extents so each `BatchWriter` flush lands in few tablets.
    Range {
        /// Sorted boundary keys; `len` ≤ workers − 1 (extra boundaries
        /// are folded into the last worker).
        splits: Vec<String>,
    },
}

/// A resolved router (policy + worker count).
#[derive(Debug, Clone)]
pub struct Sharder {
    policy: ShardPolicy,
    workers: usize,
}

impl Sharder {
    /// Build a router for `workers` workers.
    pub fn new(policy: ShardPolicy, workers: usize) -> Self {
        let policy = match policy {
            ShardPolicy::Range { mut splits } => {
                splits.sort();
                splits.dedup();
                splits.truncate(workers.saturating_sub(1));
                ShardPolicy::Range { splits }
            }
            p => p,
        };
        Sharder { policy, workers }
    }

    /// Worker index for a row key.
    pub fn route(&self, row: &str) -> usize {
        match &self.policy {
            ShardPolicy::Hash => (fnv1a(row.as_bytes()) as usize) % self.workers,
            ShardPolicy::Range { splits } => {
                // partition_point: first boundary greater than row.
                splits.partition_point(|s| s.as_str() <= row)
            }
        }
    }

    /// Replace range boundaries (no-op for hash sharding). New splits
    /// are re-fitted to the worker count exactly like `new`.
    pub fn rebalance(&mut self, splits: &[String]) {
        if let ShardPolicy::Range { .. } = self.policy {
            let refit = Sharder::new(
                ShardPolicy::Range { splits: even_subsample(splits, self.workers - 1) },
                self.workers,
            );
            self.policy = refit.policy;
        }
    }
}

/// FNV-1a 64-bit hash.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Pick `k` roughly-evenly-spaced boundaries from a sorted key list.
pub(crate) fn even_subsample(splits: &[String], k: usize) -> Vec<String> {
    if k == 0 || splits.is_empty() {
        return Vec::new();
    }
    if splits.len() <= k {
        return splits.to_vec();
    }
    (1..=k)
        .map(|i| splits[i * splits.len() / (k + 1)].clone())
        .collect()
}

/// Derive `k` split points from a (not necessarily sorted) key sample —
/// used to pre-split tables / pre-shard pipelines before a large ingest.
pub fn sample_split_points(sample: &[String], k: usize) -> Vec<String> {
    let mut sorted = sample.to_vec();
    sorted.sort();
    sorted.dedup();
    even_subsample(&sorted, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn hash_routing_in_range_and_balanced() {
        let s = Sharder::new(ShardPolicy::Hash, 4);
        let mut counts = [0usize; 4];
        let mut r = SplitMix64::new(1);
        for _ in 0..8000 {
            let key = r.below(1_000_000).to_string();
            let w = s.route(&key);
            assert!(w < 4);
            counts[w] += 1;
        }
        for &c in &counts {
            assert!((1600..=2400).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn hash_routing_is_deterministic() {
        let s = Sharder::new(ShardPolicy::Hash, 8);
        assert_eq!(s.route("somekey"), s.route("somekey"));
    }

    #[test]
    fn range_routing_boundaries() {
        let s = Sharder::new(
            ShardPolicy::Range { splits: vec!["g".into(), "p".into()] },
            3,
        );
        assert_eq!(s.route("a"), 0);
        assert_eq!(s.route("f"), 0);
        assert_eq!(s.route("g"), 1); // boundary belongs to the right shard
        assert_eq!(s.route("o"), 1);
        assert_eq!(s.route("p"), 2);
        assert_eq!(s.route("z"), 2);
    }

    #[test]
    fn range_with_no_splits_routes_all_to_zero() {
        let s = Sharder::new(ShardPolicy::Range { splits: vec![] }, 4);
        assert_eq!(s.route("anything"), 0);
    }

    #[test]
    fn excess_splits_truncated_to_workers() {
        let s = Sharder::new(
            ShardPolicy::Range {
                splits: vec!["b".into(), "c".into(), "d".into(), "e".into()],
            },
            2,
        );
        // Only 1 boundary survives for 2 workers.
        assert_eq!(s.route("a"), 0);
        assert_eq!(s.route("z"), 1);
    }

    #[test]
    fn rebalance_changes_routing() {
        let mut s = Sharder::new(ShardPolicy::Range { splits: vec![] }, 2);
        assert_eq!(s.route("m"), 0);
        s.rebalance(&["m".to_string()]);
        assert_eq!(s.route("l"), 0);
        assert_eq!(s.route("m"), 1);
    }

    #[test]
    fn sample_split_points_even() {
        let sample: Vec<String> = (0..100).map(|i| format!("{i:03}")).collect();
        let sp = sample_split_points(&sample, 3);
        assert_eq!(sp.len(), 3);
        assert!(sp.windows(2).all(|w| w[0] < w[1]));
        // Roughly the quartiles.
        assert_eq!(sp, vec!["025".to_string(), "050".to_string(), "075".to_string()]);
    }

    #[test]
    fn even_subsample_edge_cases() {
        assert!(even_subsample(&[], 3).is_empty());
        assert!(even_subsample(&["a".into()], 0).is_empty());
        let two = vec!["a".to_string(), "b".to_string()];
        assert_eq!(even_subsample(&two, 5), two);
    }
}
