//! Sorted-set algebra: the index-map machinery of paper §II.C.
//!
//! D4M's associative-array operations reduce to sparse-matrix operations
//! *after* aligning the operands' key spaces. That alignment is done by
//! two primitives over repetition-free sorted sequences:
//!
//! * [`sorted_union`] — `K = I ∪ J` plus index maps `I → K` and `J → K`
//!   (used by element-wise addition, which lives on `(I₁∪I₂) × (J₁∪J₂)`).
//! * [`sorted_intersect`] — `K = I ∩ J` plus index maps `K → I` and
//!   `K → J` (used by element-wise multiplication and by `@`, which
//!   contracts over `A.col ∩ B.row`).
//!
//! Both are the single alternating merge pass the paper describes, O(|I| +
//! |J|), constructing the index maps concurrently with the merge.
//!
//! The module also provides [`sort_dedup_with_index`], the constructor's
//! workhorse: sort a key list, deduplicate it, and return for each input
//! position the index of its key in the deduplicated output — plus the
//! dictionary-encoded fast path ([`KeyDict`], [`encode_keys_par`],
//! [`sort_dedup_encoded`]) that interns keys to dense `u32` ids and
//! sorts only the distinct keys (PR 4's encode-once constructor).

mod dict;
mod keysort;
mod merge;
mod search;

pub use dict::{encode_keys, encode_keys_par, KeyDict};
pub use keysort::{
    sort_dedup_encoded, sort_dedup_keys, sort_dedup_keys_par, sort_dedup_strs, sort_dedup_strs_par,
};
pub use merge::{sorted_intersect, sorted_union, Intersection, Union};
pub use search::{lower_bound, range_indices, upper_bound};

/// Sort + deduplicate `keys`, returning `(unique_sorted, index_map)` where
/// `index_map[p]` is the position of `keys[p]` in `unique_sorted`.
///
/// This is the shared first step of the `Assoc` constructor for the row
/// keys, column keys, and (string-valued) value pool. Cloning is avoided
/// by sorting an index permutation and moving keys out once.
pub fn sort_dedup_with_index<T: Ord + Clone>(keys: &[T]) -> (Vec<T>, Vec<usize>) {
    let n = keys.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    // Sort a permutation of positions by key, then walk it assigning
    // dense ranks. `sort_unstable_by` on indices avoids moving the keys.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    perm.sort_unstable_by(|&a, &b| keys[a as usize].cmp(&keys[b as usize]));

    let mut unique: Vec<T> = Vec::new();
    let mut index_map = vec![0usize; n];
    for &p in &perm {
        let k = &keys[p as usize];
        if unique.last() != Some(k) {
            unique.push(k.clone());
        }
        index_map[p as usize] = unique.len() - 1;
    }
    (unique, index_map)
}

/// Check that a slice is strictly increasing (sorted + repetition-free).
pub fn is_sorted_unique<T: Ord>(xs: &[T]) -> bool {
    xs.windows(2).all(|w| w[0] < w[1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn sort_dedup_basic() {
        let keys = vec!["b", "a", "b", "c", "a"];
        let (unique, map) = sort_dedup_with_index(&keys);
        assert_eq!(unique, vec!["a", "b", "c"]);
        assert_eq!(map, vec![1, 0, 1, 2, 0]);
    }

    #[test]
    fn sort_dedup_empty() {
        let (unique, map) = sort_dedup_with_index::<String>(&[]);
        assert!(unique.is_empty());
        assert!(map.is_empty());
    }

    #[test]
    fn sort_dedup_single() {
        let (unique, map) = sort_dedup_with_index(&[7i64]);
        assert_eq!(unique, vec![7]);
        assert_eq!(map, vec![0]);
    }

    #[test]
    fn sort_dedup_all_equal() {
        let keys = vec!["x"; 10];
        let (unique, map) = sort_dedup_with_index(&keys);
        assert_eq!(unique, vec!["x"]);
        assert!(map.iter().all(|&i| i == 0));
    }

    #[test]
    fn is_sorted_unique_cases() {
        assert!(is_sorted_unique::<i32>(&[]));
        assert!(is_sorted_unique(&[1]));
        assert!(is_sorted_unique(&[1, 2, 3]));
        assert!(!is_sorted_unique(&[1, 1, 2]));
        assert!(!is_sorted_unique(&[2, 1]));
    }

    #[test]
    fn prop_sort_dedup_roundtrip() {
        check("sort_dedup: unique[map[p]] == keys[p]", 300, |g| {
            let keys = g.vec_of(64, |r| r.below(20).to_string());
            let (unique, map) = sort_dedup_with_index(&keys);
            assert!(is_sorted_unique(&unique));
            assert_eq!(map.len(), keys.len());
            for (p, k) in keys.iter().enumerate() {
                assert_eq!(&unique[map[p]], k);
            }
            // Every unique element is hit by the map.
            let mut hit = vec![false; unique.len()];
            for &i in &map {
                hit[i] = true;
            }
            assert!(hit.iter().all(|&h| h));
        });
    }
}
