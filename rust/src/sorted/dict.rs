//! Dictionary encoding for [`Key`] vectors — the constructor's
//! encode-once path (PR 4).
//!
//! The digest sort in [`super::keysort`] already makes the constructor's
//! sort+dedup cheap *per comparison*, but it still sorts one element per
//! input **cell**. Associative-array workloads are heavily duplicated
//! (the paper's Figures 3–4 workload has 8 cells per distinct key;
//! scan-to-assoc rebuilds commonly have far more), so the asymptotically
//! right move is the D4M dictionary trick: intern every key to a dense
//! `u32` id in one O(n) hashing pass, sort only the *distinct* keys,
//! and recover each input position's rank through the id — strings are
//! compared (and copied) once per distinct key instead of once per cell.
//!
//! [`encode_keys_par`] is a drop-in replacement for
//! [`super::sort_dedup_keys_par`]: both produce the **canonical**
//! `(unique_sorted, index_map)` form, so the two paths are bit-identical
//! for every input and thread count (`tests/dict_equivalence.rs`
//! enforces this; [`crate::assoc::KeyEncoding`] selects between them).

use super::keysort::{sort_dedup_encoded, sort_dedup_keys};
use crate::assoc::Key;
use crate::util::intern::Dict;
use crate::util::parallel::{parallel_map_ranges, Parallelism};

/// A dense [`Key`] dictionary: the generic intern core
/// ([`crate::util::intern::Dict`]) instantiated over mixed
/// numeric/string keys, so the constructor path can encode any key
/// space. `intern`, the run-of-equal-keys cache, and the dense-id
/// accessors are the shared machinery; only the [`Key`]-ordered
/// finalize below is specific to this instantiation.
pub type KeyDict = Dict<Key>;

impl Dict<Key> {
    /// Order-preserving finalize: the canonical sorted-unique key list
    /// plus `rank[id]` = position of key `id` in it (numbers before
    /// strings — [`Key`]'s total order). The id path composes through
    /// [`sort_dedup_encoded`].
    pub fn into_sorted(self) -> (Vec<Key>, Vec<usize>) {
        sort_dedup_keys(&self.into_keys())
    }
}

/// Inputs shorter than this encode faster serially than the fan-out
/// costs (mirrors `keysort`'s threshold).
const PAR_MIN_LEN: usize = 512;

/// Dictionary-encoded sort+dedup: same `(unique_sorted, index_map)`
/// contract (and bit-identical output) as
/// [`super::sort_dedup_keys_par`], via intern → sort-distinct → rank.
///
/// Parallel path: contiguous shards intern into local dictionaries, the
/// shard dictionaries are concatenated and canonicalized with one
/// digest sort over the (few) distinct keys, and every position's rank
/// is recovered through its shard-local id. The output is a pure
/// function of the input, so every thread count matches the serial
/// path byte for byte.
pub fn encode_keys_par(keys: &[Key], par: Parallelism) -> (Vec<Key>, Vec<usize>) {
    let n = keys.len();
    if par.is_serial() || n < PAR_MIN_LEN {
        return encode_keys(keys);
    }
    let ranges = par.chunk_ranges(n);
    if ranges.len() <= 1 {
        return encode_keys(keys);
    }
    let shards: Vec<(Vec<Key>, Vec<u32>)> = parallel_map_ranges(ranges.clone(), |r| {
        let mut dict = KeyDict::new();
        let ids: Vec<u32> = keys[r].iter().map(|k| dict.intern(k)).collect();
        (dict.into_keys(), ids)
    });

    // Concatenate the shard dictionaries (moves, no clones) and
    // canonicalize once: `sort_dedup_keys` merges cross-shard
    // duplicates and yields each concatenated position's rank.
    let mut offsets = Vec::with_capacity(shards.len());
    let mut all_dict: Vec<Key> = Vec::with_capacity(shards.iter().map(|(d, _)| d.len()).sum());
    let mut shard_ids: Vec<Vec<u32>> = Vec::with_capacity(shards.len());
    for (dict, ids) in shards {
        offsets.push(all_dict.len());
        all_dict.extend(dict);
        shard_ids.push(ids);
    }
    let (unique, rank) = sort_dedup_keys(&all_dict);

    let mut index_map = vec![0usize; n];
    for ((range, ids), off) in ranges.into_iter().zip(&shard_ids).zip(&offsets) {
        for (p, &id) in range.zip(ids) {
            index_map[p] = rank[off + id as usize];
        }
    }
    (unique, index_map)
}

/// Serial dictionary encode (the `threads == 1` code path of
/// [`encode_keys_par`]).
pub fn encode_keys(keys: &[Key]) -> (Vec<Key>, Vec<usize>) {
    let mut dict = KeyDict::new();
    let ids: Vec<u32> = keys.iter().map(|k| dict.intern(k)).collect();
    sort_dedup_encoded(&dict.into_keys(), &ids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sorted::{is_sorted_unique, sort_dedup_keys_par};
    use crate::util::prop::check;

    #[test]
    fn keydict_dense_ids_and_order_preserving_finalize() {
        let mut d = KeyDict::new();
        let ks = [Key::str("m"), Key::num(3.0), Key::str("a"), Key::num(3.0), Key::str("m")];
        let ids: Vec<u32> = ks.iter().map(|k| d.intern(k)).collect();
        assert_eq!(ids, vec![0, 1, 2, 1, 0]);
        assert_eq!(d.len(), 3);
        assert_eq!(d.get(1), &Key::num(3.0));
        let (sorted, rank) = d.into_sorted();
        // Numbers sort before strings (Key's total order).
        assert_eq!(sorted, vec![Key::num(3.0), Key::str("a"), Key::str("m")]);
        assert_eq!(rank, vec![2, 0, 1]);
        assert!(is_sorted_unique(&sorted));
    }

    #[test]
    fn keydict_run_cache() {
        let mut d = KeyDict::new();
        for _ in 0..4 {
            assert_eq!(d.intern(&Key::str("r")), 0);
        }
        assert_eq!(d.intern(&Key::num(1.0)), 1);
        assert_eq!(d.intern(&Key::num(1.0)), 1);
        assert_eq!(d.intern(&Key::str("r")), 0);
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn keydict_negative_zero_is_one_key() {
        let mut d = KeyDict::new();
        let a = d.intern(&Key::num(0.0));
        let b = d.intern(&Key::Num(-0.0)); // bypasses Key::num normalization
        assert_eq!(a, b, "-0.0 must intern to the id of 0.0");
    }

    #[test]
    fn encode_matches_digest_sort_small() {
        let keys: Vec<Key> =
            ["17", "3", "17", "100", "2", "3", "99"].iter().map(|s| Key::str(*s)).collect();
        assert_eq!(encode_keys(&keys), sort_dedup_keys(&keys));
    }

    #[test]
    fn prop_encode_matches_digest_sort_all_threads() {
        check("encode_keys_par == sort_dedup_keys_par", 40, |g| {
            let len = g.rng().below_usize(1800);
            let keys: Vec<Key> = (0..len)
                .map(|_| match g.rng().below(4) {
                    0 => Key::str(g.rng().below(40).to_string()),
                    1 => Key::num(g.rng().range_i64(-40, 40) as f64),
                    2 => {
                        let mut s = "sharedprefix".to_string();
                        s.push_str(&g.rng().below(25).to_string());
                        Key::str(s)
                    }
                    _ => Key::num(g.rng().f64() * 10.0 - 5.0),
                })
                .collect();
            let expect = sort_dedup_keys(&keys);
            assert_eq!(encode_keys(&keys), expect, "serial encode");
            for threads in [2, 4, 7] {
                let par = Parallelism::with_threads(threads);
                assert_eq!(encode_keys_par(&keys, par), expect, "encode t={threads}");
                assert_eq!(sort_dedup_keys_par(&keys, par), expect, "digest t={threads}");
            }
        });
    }

    #[test]
    fn encode_empty() {
        let (u, m) = encode_keys(&[]);
        assert!(u.is_empty() && m.is_empty());
    }
}
