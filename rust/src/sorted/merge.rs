//! Sorted union / sorted intersection with concurrently built index maps
//! — the alternating-merge procedures of paper §II.C.1–3.

use std::cmp::Ordering;

/// Result of [`sorted_union`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Union<T> {
    /// `K = I ∪ J`, sorted and repetition-free.
    pub keys: Vec<T>,
    /// `map_left[m]` = position of `I[m]` in `keys`.
    pub map_left: Vec<usize>,
    /// `map_right[n]` = position of `J[n]` in `keys`.
    pub map_right: Vec<usize>,
}

/// Result of [`sorted_intersect`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Intersection<T> {
    /// `K = I ∩ J`, sorted and repetition-free.
    pub keys: Vec<T>,
    /// `map_left[k]` = position of `keys[k]` in `I`.
    pub map_left: Vec<usize>,
    /// `map_right[k]` = position of `keys[k]` in `J`.
    pub map_right: Vec<usize>,
}

/// Sorted union of two repetition-free sorted slices, with index maps
/// describing how each input sits inside the union (paper §II.C.1).
///
/// Runs in `O(|left| + |right|)`; the three cases of the loop body are
/// exactly the paper's Case 1–3 alternating iteration.
pub fn sorted_union<T: Ord + Clone>(left: &[T], right: &[T]) -> Union<T> {
    debug_assert!(super::is_sorted_unique(left));
    debug_assert!(super::is_sorted_unique(right));
    let mut keys = Vec::with_capacity(left.len() + right.len());
    let mut map_left = Vec::with_capacity(left.len());
    let mut map_right = Vec::with_capacity(right.len());
    let (mut m, mut n) = (0usize, 0usize);
    while m < left.len() && n < right.len() {
        match left[m].cmp(&right[n]) {
            Ordering::Less => {
                map_left.push(keys.len());
                keys.push(left[m].clone());
                m += 1;
            }
            Ordering::Equal => {
                map_left.push(keys.len());
                map_right.push(keys.len());
                keys.push(left[m].clone());
                m += 1;
                n += 1;
            }
            Ordering::Greater => {
                map_right.push(keys.len());
                keys.push(right[n].clone());
                n += 1;
            }
        }
    }
    // One (or both) inputs exhausted: append the tail.
    while m < left.len() {
        map_left.push(keys.len());
        keys.push(left[m].clone());
        m += 1;
    }
    while n < right.len() {
        map_right.push(keys.len());
        keys.push(right[n].clone());
        n += 1;
    }
    Union { keys, map_left, map_right }
}

/// Sorted intersection of two repetition-free sorted slices, with index
/// maps describing where each common key sits in the inputs (§II.C.2).
pub fn sorted_intersect<T: Ord + Clone>(left: &[T], right: &[T]) -> Intersection<T> {
    debug_assert!(super::is_sorted_unique(left));
    debug_assert!(super::is_sorted_unique(right));
    let cap = left.len().min(right.len());
    let mut keys = Vec::with_capacity(cap);
    let mut map_left = Vec::with_capacity(cap);
    let mut map_right = Vec::with_capacity(cap);
    let (mut m, mut n) = (0usize, 0usize);
    while m < left.len() && n < right.len() {
        match left[m].cmp(&right[n]) {
            Ordering::Less => m += 1,
            Ordering::Greater => n += 1,
            Ordering::Equal => {
                map_left.push(m);
                map_right.push(n);
                keys.push(left[m].clone());
                m += 1;
                n += 1;
            }
        }
    }
    Intersection { keys, map_left, map_right }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sorted::is_sorted_unique;
    use crate::util::prop::check;
    use std::collections::BTreeSet;

    #[test]
    fn union_disjoint() {
        let u = sorted_union(&[1, 3, 5], &[2, 4, 6]);
        assert_eq!(u.keys, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(u.map_left, vec![0, 2, 4]);
        assert_eq!(u.map_right, vec![1, 3, 5]);
    }

    #[test]
    fn union_overlapping() {
        let u = sorted_union(&["a", "b", "d"], &["b", "c", "d"]);
        assert_eq!(u.keys, vec!["a", "b", "c", "d"]);
        assert_eq!(u.map_left, vec![0, 1, 3]);
        assert_eq!(u.map_right, vec![1, 2, 3]);
    }

    #[test]
    fn union_one_empty() {
        let u = sorted_union::<i32>(&[], &[1, 2]);
        assert_eq!(u.keys, vec![1, 2]);
        assert!(u.map_left.is_empty());
        assert_eq!(u.map_right, vec![0, 1]);
        let u = sorted_union::<i32>(&[1, 2], &[]);
        assert_eq!(u.keys, vec![1, 2]);
        assert_eq!(u.map_left, vec![0, 1]);
    }

    #[test]
    fn union_identical() {
        let u = sorted_union(&[1, 2, 3], &[1, 2, 3]);
        assert_eq!(u.keys, vec![1, 2, 3]);
        assert_eq!(u.map_left, u.map_right);
    }

    #[test]
    fn intersect_basic() {
        let i = sorted_intersect(&["a", "b", "c", "e"], &["b", "d", "e"]);
        assert_eq!(i.keys, vec!["b", "e"]);
        assert_eq!(i.map_left, vec![1, 3]);
        assert_eq!(i.map_right, vec![0, 2]);
    }

    #[test]
    fn intersect_disjoint_and_empty() {
        let i = sorted_intersect(&[1, 3], &[2, 4]);
        assert!(i.keys.is_empty());
        let i = sorted_intersect::<i32>(&[], &[1]);
        assert!(i.keys.is_empty());
    }

    #[test]
    fn prop_union_matches_btreeset() {
        check("sorted_union == BTreeSet union", 300, |g| {
            let a = g.sorted_unique_keys(40, 30);
            let b = g.sorted_unique_keys(40, 30);
            let u = sorted_union(&a, &b);
            let expect: Vec<String> = a
                .iter()
                .chain(b.iter())
                .cloned()
                .collect::<BTreeSet<_>>()
                .into_iter()
                .collect();
            assert_eq!(u.keys, expect);
            assert!(is_sorted_unique(&u.keys));
            // Index maps are correct embeddings.
            for (m, k) in a.iter().enumerate() {
                assert_eq!(&u.keys[u.map_left[m]], k);
            }
            for (n, k) in b.iter().enumerate() {
                assert_eq!(&u.keys[u.map_right[n]], k);
            }
        });
    }

    #[test]
    fn prop_intersect_matches_btreeset() {
        check("sorted_intersect == BTreeSet intersection", 300, |g| {
            let a = g.sorted_unique_keys(40, 30);
            let b = g.sorted_unique_keys(40, 30);
            let i = sorted_intersect(&a, &b);
            let sa: BTreeSet<_> = a.iter().cloned().collect();
            let sb: BTreeSet<_> = b.iter().cloned().collect();
            let expect: Vec<String> = sa.intersection(&sb).cloned().collect();
            assert_eq!(i.keys, expect);
            for (k, key) in i.keys.iter().enumerate() {
                assert_eq!(&a[i.map_left[k]], key);
                assert_eq!(&b[i.map_right[k]], key);
            }
        });
    }

    #[test]
    fn prop_union_intersect_inclusion_exclusion() {
        check("|I∪J| + |I∩J| == |I| + |J|", 200, |g| {
            let a = g.sorted_unique_keys(50, 25);
            let b = g.sorted_unique_keys(50, 25);
            let u = sorted_union(&a, &b);
            let i = sorted_intersect(&a, &b);
            assert_eq!(u.keys.len() + i.keys.len(), a.len() + b.len());
        });
    }
}
