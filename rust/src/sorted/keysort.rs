//! Specialized sort+dedup for [`Key`] vectors — the constructor's hot
//! path (paper Figures 3–4).
//!
//! The generic [`super::sort_dedup_with_index`] sorts an index
//! permutation, so every comparison pays two random accesses into the
//! key vector plus an enum-discriminant branch plus a `memcmp` call;
//! profiling shows that dominating the whole constructor (≈65% of
//! samples). This path instead sorts `(prefix, index)` pairs where
//! `prefix` is an order-preserving 64-bit digest:
//!
//! * string keys: the first 8 bytes, big-endian (ties → full compare,
//!   but the paper's integer-cast keys are ≤ 7 bytes, so prefix order
//!   *is* total order for the bench workloads);
//! * numeric keys: the IEEE-754 total-order bit trick;
//! * numbers sort before strings via the top tag bit, matching
//!   [`Key`]'s `Ord`.
//!
//! Comparisons become branch-predictable `u64` compares with the data
//! inline in the sorted buffer — no pointer chasing.
//!
//! **Parallelism.** [`sort_dedup_keys_par`] / [`sort_dedup_strs_par`]
//! shard the input into contiguous chunks, run the serial digest sort
//! on each shard in a pool worker, then fold the shard results together
//! with [`sorted_union`](super::sorted_union), composing the per-shard
//! index maps through the union's embedding maps. The output —
//! canonical sorted-unique keys plus positions — is identical to the
//! serial path for every thread count, because both compute the same
//! canonical form.

use super::sorted_union;
use crate::assoc::Key;
use crate::util::parallel::{parallel_map_ranges, Parallelism};

/// Order-preserving 64-bit digest of a key, plus whether the digest is
/// exact (no tie-break needed).
///
/// Layout: bit 63 = tag (0 numeric, 1 string); remaining bits hold the
/// scaled ordering payload. Exactness: numeric digests lose the f64's
/// low bit to the tag shift only when the exponent is extreme, so we
/// keep numerics conservative; string digests are exact iff the key
/// fits the bit-shifted prefix (len ≤ 7) **and** has no trailing NUL —
/// zero padding makes `"abc"` and `"abc\0"` digest-equal, so a
/// trailing NUL must force the tie-break full compare (the same
/// invariant as `util::intern::digest_sort_strs`).
#[inline]
fn digest(k: &Key) -> (u64, bool) {
    match k {
        Key::Num(v) => {
            // IEEE total-order: flip all bits for negatives, set the
            // sign bit for positives. Result compared as u64 orders
            // like f64. Shift right 1 to make room for the tag bit.
            // -0.0 (only reachable by building the enum variant
            // directly; `Key::num` normalizes) must digest like 0.0,
            // which it equals as a key.
            let bits = if *v == 0.0 { 0.0f64 } else { *v }.to_bits();
            let ord = if bits >> 63 == 1 { !bits } else { bits | (1 << 63) };
            ((ord >> 1), false) // conservative: tie-break confirms
        }
        Key::Str(s) => {
            let b = s.as_bytes();
            let mut p = [0u8; 8];
            let n = b.len().min(8);
            p[..n].copy_from_slice(&b[..n]);
            // Exact only when the whole key fits the (bit-shifted)
            // prefix AND it has no trailing NUL — zero padding makes
            // "abc" and "abc\0" digest-equal, so a trailing NUL must
            // force the tie-break compare.
            let exact = b.len() <= 7 && b.last() != Some(&0);
            ((1 << 63) | (u64::from_be_bytes(p) >> 1), exact)
        }
    }
}

/// Sort + deduplicate, returning `(unique_sorted, index_map)` with
/// `unique_sorted[index_map[p]] == keys[p]` — drop-in replacement for
/// the generic path, specialized to [`Key`].
pub fn sort_dedup_keys(keys: &[Key]) -> (Vec<Key>, Vec<usize>) {
    let n = keys.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let mut tagged: Vec<(u64, u32)> = Vec::with_capacity(n);
    let mut all_exact = true;
    for (i, k) in keys.iter().enumerate() {
        let (d, exact) = digest(k);
        all_exact &= exact;
        tagged.push((d, i as u32));
    }
    if all_exact {
        // Digest order is total: pure u64 sort, no fallback compares.
        tagged.sort_unstable();
    } else {
        tagged.sort_unstable_by(|a, b| {
            a.0.cmp(&b.0)
                .then_with(|| keys[a.1 as usize].cmp(&keys[b.1 as usize]))
        });
    }
    let mut unique: Vec<Key> = Vec::new();
    let mut index_map = vec![0usize; n];
    let mut last_digest = 0u64;
    for &(d, p) in &tagged {
        let k = &keys[p as usize];
        // Cheap digest check first; when digests are exact, equality of
        // digests IS equality of keys — no byte compare at all.
        let is_new = match unique.last() {
            None => true,
            Some(_) if all_exact => d != last_digest,
            Some(prev) => d != last_digest || prev != k,
        };
        if is_new {
            unique.push(k.clone());
            last_digest = d;
        }
        index_map[p as usize] = unique.len() - 1;
    }
    (unique, index_map)
}

/// Sort + deduplicate a string list the same way — used for the string
/// value pool of the `Assoc` constructor (paper Figure 4). With no
/// numeric/string tag bit needed, the digest is the full first 8 bytes,
/// so it is *exact* for strings up to length 8 (the paper's length-8
/// random value workload sorts without a single byte-compare).
pub fn sort_dedup_strs(vals: &[String]) -> (Vec<String>, Vec<usize>) {
    let n = vals.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    // The digest-pair sort is shared with `StrDict::into_sorted` (one
    // home for the prefix/trailing-NUL exactness invariant); when every
    // digest is exact, dedup below is a pure u64 compare too.
    let (tagged, all_exact) = crate::util::intern::digest_sort_strs(vals);
    let mut unique: Vec<String> = Vec::new();
    let mut index_map = vec![0usize; n];
    let mut last_digest = 0u64;
    for &(d, p) in &tagged {
        let s = &vals[p as usize];
        let is_new = match unique.last() {
            None => true,
            Some(_) if all_exact => d != last_digest,
            Some(prev) => d != last_digest || prev != s,
        };
        if is_new {
            unique.push(s.clone());
            last_digest = d;
        }
        index_map[p as usize] = unique.len() - 1;
    }
    (unique, index_map)
}

/// The id path: canonical `(unique_sorted, index_map)` from a
/// dictionary encode. `dict` holds the *distinct* keys (any order, no
/// repeats — a [`crate::sorted::KeyDict`]'s id space) and `ids[p]` is
/// position `p`'s dense id, so only `dict.len()` keys are sorted and
/// every input position resolves through an O(1) rank lookup —
/// bit-identical to [`sort_dedup_keys`] over the decoded input.
pub fn sort_dedup_encoded(dict: &[Key], ids: &[u32]) -> (Vec<Key>, Vec<usize>) {
    let (unique, rank) = sort_dedup_keys(dict);
    debug_assert_eq!(
        unique.len(),
        dict.len(),
        "dictionary ids must be distinct (duplicates would skew ranks)"
    );
    let index_map = ids.iter().map(|&id| rank[id as usize]).collect();
    (unique, index_map)
}

/// Inputs shorter than this sort faster serially than the fan-out costs.
const PAR_MIN_LEN: usize = 512;

/// [`sort_dedup_keys`] with an explicit thread configuration:
/// shard-sort + union-merge (see the module docs). `threads == 1` is
/// the exact serial code path.
pub fn sort_dedup_keys_par(keys: &[Key], par: Parallelism) -> (Vec<Key>, Vec<usize>) {
    shard_sort_dedup(keys, par, sort_dedup_keys)
}

/// [`sort_dedup_strs`] with an explicit thread configuration.
pub fn sort_dedup_strs_par(vals: &[String], par: Parallelism) -> (Vec<String>, Vec<usize>) {
    shard_sort_dedup(vals, par, sort_dedup_strs)
}

/// Shard-parallel sort+dedup: run `serial` on contiguous shards, fold
/// the shard uniques with [`sorted_union`], and compose each shard's
/// index map through the union embeddings. Produces the same canonical
/// `(unique_sorted, index_map)` as `serial` on the whole input.
fn shard_sort_dedup<T, F>(items: &[T], par: Parallelism, serial: F) -> (Vec<T>, Vec<usize>)
where
    T: Ord + Clone + Send + Sync,
    F: Fn(&[T]) -> (Vec<T>, Vec<usize>) + Sync,
{
    let n = items.len();
    if par.is_serial() || n < PAR_MIN_LEN {
        return serial(items);
    }
    let ranges = par.chunk_ranges(n);
    if ranges.len() <= 1 {
        return serial(items);
    }
    let shards: Vec<(Vec<T>, Vec<usize>)> =
        parallel_map_ranges(ranges.clone(), |r| serial(&items[r]));

    // Fold the shard uniques left-to-right. `remaps[s][i]` tracks where
    // shard s's i-th unique key currently sits in the accumulated union.
    let mut shard_maps: Vec<Vec<usize>> = Vec::with_capacity(shards.len());
    let mut remaps: Vec<Vec<usize>> = Vec::with_capacity(shards.len());
    let mut acc: Vec<T> = Vec::new();
    for (uniq, map) in shards {
        if acc.is_empty() {
            remaps.push((0..uniq.len()).collect());
            acc = uniq;
        } else {
            let u = sorted_union(&acc, &uniq);
            for rm in &mut remaps {
                for v in rm.iter_mut() {
                    *v = u.map_left[*v];
                }
            }
            remaps.push(u.map_right);
            acc = u.keys;
        }
        shard_maps.push(map);
    }

    let mut index_map = vec![0usize; n];
    for ((range, rm), smap) in ranges.into_iter().zip(&remaps).zip(&shard_maps) {
        for (off, p) in range.enumerate() {
            index_map[p] = rm[smap[off]];
        }
    }
    (acc, index_map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sorted::{is_sorted_unique, sort_dedup_with_index};
    use crate::util::prop::check;

    #[test]
    fn digest_orders_like_key_ord() {
        let keys = [
            Key::num(-1e300),
            Key::num(-2.5),
            Key::num(0.0),
            Key::num(3.0),
            Key::num(1e300),
            Key::str(""),
            Key::str("a"),
            Key::str("abcdefgh"),
            Key::str("b"),
        ];
        for i in 0..keys.len() {
            for j in 0..keys.len() {
                let (di, _) = digest(&keys[i]);
                let (dj, _) = digest(&keys[j]);
                match keys[i].cmp(&keys[j]) {
                    std::cmp::Ordering::Less => assert!(di <= dj, "{i} vs {j}"),
                    std::cmp::Ordering::Greater => assert!(di >= dj, "{i} vs {j}"),
                    std::cmp::Ordering::Equal => assert_eq!(di, dj),
                }
            }
        }
    }

    #[test]
    fn matches_generic_on_bench_keys() {
        // Integer-cast string keys, the Figures 3-7 workload shape.
        let keys: Vec<Key> =
            ["17", "3", "17", "100", "2", "3", "99"].iter().map(|s| Key::str(*s)).collect();
        let (u1, m1) = sort_dedup_keys(&keys);
        let (u2, m2) = sort_dedup_with_index(&keys);
        assert_eq!(u1, u2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn prop_matches_generic_path() {
        check("sort_dedup_keys == generic", 300, |g| {
            let mode = g.rng().below(3);
            let len = g.rng().below_usize(120);
            let keys: Vec<Key> = (0..len)
                .map(|_| match mode {
                    0 => Key::str(g.rng().below(40).to_string()), // short strings
                    1 => Key::num(g.rng().range_i64(-50, 50) as f64), // numerics
                    _ => {
                        // mixed, incl. long strings with shared prefixes
                        if g.rng().chance(0.5) {
                            let mut s = "sharedprefix".to_string();
                            s.push_str(&g.rng().below(20).to_string());
                            Key::str(s)
                        } else {
                            Key::num(g.rng().f64() * 100.0 - 50.0)
                        }
                    }
                })
                .collect();
            let (u1, m1) = sort_dedup_keys(&keys);
            let (u2, m2) = sort_dedup_with_index(&keys);
            assert_eq!(u1, u2, "unique mismatch");
            assert_eq!(m1, m2, "index map mismatch");
            assert!(is_sorted_unique(&u1));
        });
    }

    #[test]
    fn prop_parallel_matches_serial() {
        check("sort_dedup_*_par == serial", 30, |g| {
            // Length above PAR_MIN_LEN so shards actually fan out.
            let len = PAR_MIN_LEN + g.rng().below_usize(1500);
            let keys: Vec<Key> = (0..len)
                .map(|_| {
                    if g.rng().chance(0.5) {
                        Key::str(g.rng().below(200).to_string())
                    } else {
                        Key::num(g.rng().range_i64(-100, 100) as f64)
                    }
                })
                .collect();
            let strs: Vec<String> = (0..len).map(|_| g.rng().ascii_lower(8)).collect();
            let (ku, km) = sort_dedup_keys(&keys);
            let (su, sm) = sort_dedup_strs(&strs);
            for threads in [2, 4, 7] {
                let par = Parallelism::with_threads(threads);
                let (ku2, km2) = sort_dedup_keys_par(&keys, par);
                assert_eq!(ku, ku2, "keys unique t={threads}");
                assert_eq!(km, km2, "keys map t={threads}");
                let (su2, sm2) = sort_dedup_strs_par(&strs, par);
                assert_eq!(su, su2, "strs unique t={threads}");
                assert_eq!(sm, sm2, "strs map t={threads}");
            }
        });
    }

    #[test]
    fn negative_zero_keys_dedup_identically() {
        // -0.0 == 0.0 as keys; serial and parallel paths must agree on
        // a single unique entry (regression: bit-level digests used to
        // split what Key::cmp merges).
        let mut keys: Vec<Key> = Vec::new();
        for i in 0..600 {
            keys.push(Key::num(if i % 3 == 0 { -0.0 } else { 0.0 }));
            keys.push(Key::num((i % 7) as f64));
        }
        let (u1, m1) = sort_dedup_keys(&keys);
        assert!(is_sorted_unique(&u1), "serial unique list must be strictly sorted");
        for threads in [2, 4, 7] {
            let (u2, m2) = sort_dedup_keys_par(&keys, Parallelism::with_threads(threads));
            assert_eq!(u1, u2, "t={threads}");
            assert_eq!(m1, m2, "t={threads}");
        }
    }

    #[test]
    fn parallel_path_small_input_falls_back() {
        let keys: Vec<Key> = ["b", "a", "b"].iter().map(|s| Key::str(*s)).collect();
        let (u, m) = sort_dedup_keys_par(&keys, Parallelism::with_threads(4));
        assert_eq!((u, m), sort_dedup_keys(&keys));
    }

    #[test]
    fn trailing_nul_keys_stay_distinct() {
        // "abc" and "abc\0" share a zero-padded prefix; the digest fast
        // path must not merge or misorder them (regression: exactness
        // used to consider any ≤7-byte string digest-exact).
        let keys: Vec<Key> =
            ["abc\0", "abc", "abc\0\0", "abc"].iter().map(|s| Key::str(*s)).collect();
        let (u, m) = sort_dedup_keys(&keys);
        let (u2, m2) = sort_dedup_with_index(&keys);
        assert_eq!(u, u2);
        assert_eq!(m, m2);
        assert_eq!(u.len(), 3);
        assert!(is_sorted_unique(&u));
        let strs: Vec<String> = ["abc\0", "abc", "abc\0\0"].iter().map(|s| s.to_string()).collect();
        let (su, sm) = sort_dedup_strs(&strs);
        assert_eq!(su, vec!["abc".to_string(), "abc\0".to_string(), "abc\0\0".to_string()]);
        assert_eq!(sm, vec![1, 0, 2]);
    }

    #[test]
    fn long_string_ties_resolved() {
        let keys: Vec<Key> = ["aaaaaaaaZZ", "aaaaaaaaAA", "aaaaaaaa", "aaaaaaaaAA"]
            .iter()
            .map(|s| Key::str(*s))
            .collect();
        let (u, m) = sort_dedup_keys(&keys);
        let want: Vec<Key> = ["aaaaaaaa", "aaaaaaaaAA", "aaaaaaaaZZ"]
            .iter()
            .map(|s| Key::str(*s))
            .collect();
        assert_eq!(u, want);
        assert_eq!(m, vec![2, 1, 0, 1]);
    }
}
