//! Binary-search helpers over sorted unique key vectors.
//!
//! D4M's "string slices" (`A["a,:,b,"]`, paper §II.B) select all keys `k`
//! with `a ≤ k ≤ b` — *inclusive on the right*, unlike Python slices.
//! [`range_indices`] maps such a closed key range onto the half-open index
//! range of a sorted key vector.

use std::cmp::Ordering;

/// Index of the first element `>= probe` (`xs` sorted ascending).
pub fn lower_bound<T: Ord>(xs: &[T], probe: &T) -> usize {
    xs.partition_point(|x| x.cmp(probe) == Ordering::Less)
}

/// Index of the first element `> probe` (`xs` sorted ascending).
pub fn upper_bound<T: Ord>(xs: &[T], probe: &T) -> usize {
    xs.partition_point(|x| x.cmp(probe) != Ordering::Greater)
}

/// Half-open index range `[start, end)` of keys in the *closed* key range
/// `[lo, hi]` — D4M string-slice semantics (inclusive both ends).
pub fn range_indices<T: Ord>(xs: &[T], lo: &T, hi: &T) -> (usize, usize) {
    (lower_bound(xs, lo), upper_bound(xs, hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn bounds_basic() {
        let xs = vec![10, 20, 20, 30]; // upper/lower work on sorted (dupes ok)
        assert_eq!(lower_bound(&xs, &20), 1);
        assert_eq!(upper_bound(&xs, &20), 3);
        assert_eq!(lower_bound(&xs, &5), 0);
        assert_eq!(upper_bound(&xs, &35), 4);
    }

    #[test]
    fn range_is_right_inclusive() {
        let xs = vec!["a", "b", "c", "d"];
        let (s, e) = range_indices(&xs, &"b", &"c");
        assert_eq!(&xs[s..e], &["b", "c"]); // "c" included — D4M semantics
    }

    #[test]
    fn range_with_absent_endpoints() {
        let xs = vec!["b", "d", "f"];
        let (s, e) = range_indices(&xs, &"a", &"e");
        assert_eq!(&xs[s..e], &["b", "d"]);
        let (s, e) = range_indices(&xs, &"g", &"z");
        assert_eq!(s, e); // empty
    }

    #[test]
    fn range_empty_input() {
        let xs: Vec<i32> = vec![];
        assert_eq!(range_indices(&xs, &1, &2), (0, 0));
    }

    #[test]
    fn prop_range_matches_filter() {
        check("range_indices == linear filter", 300, |g| {
            let xs = g.sorted_unique_keys(50, 40);
            let lo = g.key_string(40);
            let hi = g.key_string(40);
            let (s, e) = range_indices(&xs, &lo, &hi);
            let expect: Vec<&String> =
                xs.iter().filter(|k| **k >= lo && **k <= hi).collect();
            let got: Vec<&String> = xs[s.min(xs.len())..e.min(xs.len()).max(s.min(xs.len()))]
                .iter()
                .collect();
            if lo <= hi {
                assert_eq!(got, expect);
            } else {
                assert!(expect.is_empty());
            }
        });
    }
}
