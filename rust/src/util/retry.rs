//! Deterministic retry-with-backoff and the storage error taxonomy.
//!
//! Every fallible storage operation in the durable tier is classified as
//! either **transient** (worth retrying: interrupted syscalls, timeouts,
//! contention) or **permanent** (retrying cannot help: corruption, a full
//! disk, missing files). [`RetryPolicy::run`] wraps an operation with a
//! bounded, seeded-jitter exponential backoff loop: permanent errors
//! surface immediately, transient errors are retried until the budget is
//! exhausted. The jitter is driven by [`SplitMix64`], so a given policy
//! produces the same delay schedule on every execution — fault-injection
//! tests and production behave identically.

use std::io;
use std::time::Duration;

use crate::util::SplitMix64;

/// Whether an I/O error is worth retrying.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorClass {
    /// Retrying may succeed (interrupted call, timeout, transient
    /// contention).
    Transient,
    /// Retrying cannot help (corruption, full disk, missing file,
    /// permission, unclassified failures).
    Permanent,
}

/// Classify an `io::Error` into the transient/permanent taxonomy.
///
/// The mapping is deliberately conservative: only error kinds that name a
/// *momentary* condition are transient; everything else — including
/// `StorageFull` (ENOSPC) and `InvalidData` (corruption) — is permanent,
/// so a retry loop never spins on a dead disk or a bad checksum.
pub fn classify(e: &io::Error) -> ErrorClass {
    match e.kind() {
        io::ErrorKind::Interrupted | io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => {
            ErrorClass::Transient
        }
        _ => ErrorClass::Permanent,
    }
}

/// A bounded, deterministic retry schedule for storage operations.
///
/// `budget` is the number of *re*-attempts after the first try (a budget
/// of 3 means at most 4 attempts). Delays grow exponentially from
/// `base_backoff`, are capped at `max_backoff`, and are jittered into
/// `[0.5, 1.0]×` of the nominal delay by a [`SplitMix64`] stream seeded
/// from `seed` — fully deterministic, no wall-clock input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Number of retries after the first attempt.
    pub budget: u32,
    /// Nominal delay before the first retry; doubles per attempt.
    pub base_backoff: Duration,
    /// Upper bound on any single delay.
    pub max_backoff: Duration,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            budget: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(50),
            seed: 0xD4A7_B0FF,
        }
    }
}

impl RetryPolicy {
    /// A policy that never retries: the operation runs exactly once.
    /// This is the "PR 6 path" — the raw storage call with no policy
    /// layer on top.
    pub fn none() -> Self {
        RetryPolicy { budget: 0, ..RetryPolicy::default() }
    }

    /// A retrying policy with zero sleep between attempts — used by
    /// tests and benches where deterministic healing matters but
    /// wall-clock delay is waste.
    pub fn immediate(budget: u32) -> Self {
        RetryPolicy {
            budget,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            ..RetryPolicy::default()
        }
    }

    /// The jittered delay before retry number `attempt` (0-based).
    fn delay(&self, attempt: u32, rng: &mut SplitMix64) -> Duration {
        let shift = attempt.min(20);
        let nominal = self
            .base_backoff
            .saturating_mul(1u32 << shift)
            .min(self.max_backoff);
        if nominal.is_zero() {
            return Duration::ZERO;
        }
        nominal.mul_f64(0.5 + 0.5 * rng.f64())
    }

    /// Run `op` under this policy. Transient failures (per [`classify`])
    /// are retried with backoff until the budget runs out; permanent
    /// failures return immediately. The returned error keeps the
    /// original [`io::ErrorKind`] (so callers can re-classify it) and
    /// appends `ctx` plus the attempt count to the message.
    pub fn run<T>(&self, ctx: &str, mut op: impl FnMut() -> io::Result<T>) -> io::Result<T> {
        let mut rng = SplitMix64::new(self.seed);
        let mut attempt: u32 = 0;
        loop {
            match op() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if classify(&e) == ErrorClass::Permanent || attempt >= self.budget {
                        return Err(io::Error::new(
                            e.kind(),
                            format!("{ctx}: {e} (attempts: {})", attempt + 1),
                        ));
                    }
                    let d = self.delay(attempt, &mut rng);
                    if !d.is_zero() {
                        std::thread::sleep(d);
                    }
                    attempt += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn transient() -> io::Error {
        io::Error::new(io::ErrorKind::Interrupted, "flaky")
    }

    #[test]
    fn classifies_kinds() {
        assert_eq!(classify(&transient()), ErrorClass::Transient);
        assert_eq!(
            classify(&io::Error::new(io::ErrorKind::TimedOut, "t")),
            ErrorClass::Transient
        );
        assert_eq!(classify(&io::Error::other("x")), ErrorClass::Permanent);
        assert_eq!(
            classify(&io::Error::new(io::ErrorKind::InvalidData, "bad crc")),
            ErrorClass::Permanent
        );
        assert_eq!(
            classify(&io::Error::new(io::ErrorKind::NotFound, "gone")),
            ErrorClass::Permanent
        );
    }

    #[test]
    fn retries_transient_until_success() {
        let calls = AtomicU32::new(0);
        let out = RetryPolicy::immediate(3).run("op", || {
            if calls.fetch_add(1, Ordering::SeqCst) < 2 {
                Err(transient())
            } else {
                Ok(7)
            }
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn budget_bounds_attempts_and_keeps_kind() {
        let calls = AtomicU32::new(0);
        let out: io::Result<()> = RetryPolicy::immediate(2).run("op", || {
            calls.fetch_add(1, Ordering::SeqCst);
            Err(transient())
        });
        let err = out.unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Interrupted);
        assert!(err.to_string().contains("attempts: 3"), "{err}");
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn permanent_errors_never_retry() {
        let calls = AtomicU32::new(0);
        let out: io::Result<()> = RetryPolicy::immediate(5).run("op", || {
            calls.fetch_add(1, Ordering::SeqCst);
            Err(io::Error::other("dead disk"))
        });
        assert!(out.is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn none_runs_exactly_once() {
        let calls = AtomicU32::new(0);
        let out: io::Result<()> = RetryPolicy::none().run("op", || {
            calls.fetch_add(1, Ordering::SeqCst);
            Err(transient())
        });
        assert!(out.is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn delays_are_deterministic_and_capped() {
        let p = RetryPolicy::default();
        let mut a = SplitMix64::new(p.seed);
        let mut b = SplitMix64::new(p.seed);
        for attempt in 0..8 {
            let da = p.delay(attempt, &mut a);
            let db = p.delay(attempt, &mut b);
            assert_eq!(da, db);
            assert!(da <= p.max_backoff);
        }
    }
}
