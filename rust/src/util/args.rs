//! A tiny command-line argument parser.
//!
//! `clap` is unavailable offline, so binaries and benches use this
//! minimal `--flag [value]` parser: flags are `--name value` pairs or
//! boolean `--name`, and anything else is a positional argument.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of argument strings.
    ///
    /// `--key value` binds `key` to `value` unless `value` itself starts
    /// with `--`, in which case `key` is treated as a boolean flag
    /// (bound to `"true"`). `--key=value` is also accepted.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            flags.insert(name.to_string(), v);
                        }
                        _ => {
                            flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                positional.push(a);
            }
        }
        Args { flags, positional }
    }

    /// Raw string flag value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// String flag with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Boolean flag: present (or `--name true`) means true.
    pub fn flag(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Integer flag with default; panics with a clear message on non-integers.
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")),
        }
    }

    /// Float flag with default.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")),
        }
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse(&["--n", "12", "--out", "results/x.csv"]);
        assert_eq!(a.usize_or("n", 0), 12);
        assert_eq!(a.str_or("out", ""), "results/x.csv");
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--n=7", "--ratio=0.5"]);
        assert_eq!(a.usize_or("n", 0), 7);
        assert!((a.f64_or("ratio", 0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["--full", "--verbose", "--n", "3"]);
        assert!(a.flag("full"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("absent"));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.str_or("b", ""), "v");
    }

    #[test]
    fn positional_args() {
        let a = parse(&["cmd", "--k", "v", "file.txt"]);
        assert_eq!(a.positional(), &["cmd".to_string(), "file.txt".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 42), 42);
        assert_eq!(a.str_or("s", "d"), "d");
        assert!((a.f64_or("f", 1.5) - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_integer_panics() {
        let a = parse(&["--n", "xyz"]);
        a.usize_or("n", 0);
    }
}
