//! Parallelism configuration and deterministic fork-join helpers.
//!
//! The compute hot paths (SpGEMM, the constructor key sort, tablet
//! scans) fan work out over the shared [`ThreadPool`], but every
//! parallel path in this crate obeys one contract: **the result is
//! byte-identical to the serial path**, for any thread count. That is
//! achieved structurally — work is split into contiguous chunks whose
//! boundaries depend only on the input and the configured thread count,
//! each chunk is computed independently, and results are stitched back
//! in chunk order. No atomics-order or scheduling nondeterminism can
//! reach the output; `rust/tests/parallel_equivalence.rs` enforces the
//! contract for every figure op and builtin semiring.
//!
//! [`Parallelism`] is the one knob: `threads == 1` selects the exact
//! serial code path (not a one-chunk parallel run), the default tracks
//! the machine's available cores, and benches sweep it via `--threads`.
//! A `D4M_THREADS` environment variable pins the default without flag
//! plumbing (CI, scripts); an explicit `--threads` / `set_default`
//! still wins.

use super::pool::ThreadPool;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Thread-count configuration for the parallel compute paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Worker count to fan out to. `1` means "run the serial code
    /// path"; `0` is normalized to `1` at construction.
    pub threads: usize,
}

/// Global default thread count; `0` = track available parallelism.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

impl Parallelism {
    /// The exact serial code path.
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// One worker per available core (at least 1).
    pub fn auto() -> Parallelism {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Parallelism { threads: n.max(1) }
    }

    /// An explicit worker count (`0` is clamped to `1`).
    pub fn with_threads(n: usize) -> Parallelism {
        Parallelism { threads: n.max(1) }
    }

    /// The worker count pinned by the `D4M_THREADS` environment
    /// variable, if set to a positive integer (cached at first read —
    /// the variable is process-configuration, not a runtime knob).
    /// Lets CI and scripts pin parallelism without flag plumbing; an
    /// explicit CLI `--threads` still wins because it installs a
    /// process default via [`Parallelism::set_default`].
    pub fn env_threads() -> Option<usize> {
        static ENV: OnceLock<usize> = OnceLock::new();
        let n = *ENV.get_or_init(|| {
            std::env::var("D4M_THREADS")
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .unwrap_or(0)
        });
        (n > 0).then_some(n)
    }

    /// The process-wide default used by the convenience entry points
    /// (`Assoc::matmul`, `Table::scan`, …): the value installed by
    /// [`Parallelism::set_default`] if any, else the `D4M_THREADS`
    /// environment variable ([`Parallelism::env_threads`]), else
    /// [`Parallelism::auto`].
    pub fn current() -> Parallelism {
        match DEFAULT_THREADS.load(Ordering::Relaxed) {
            0 => match Parallelism::env_threads() {
                Some(n) => Parallelism { threads: n },
                None => Parallelism::auto(),
            },
            n => Parallelism { threads: n },
        }
    }

    /// Install `self` as the process-wide default (benches use this to
    /// sweep `--threads`). Affects only entry points that don't take an
    /// explicit `Parallelism`.
    pub fn set_default(self) {
        DEFAULT_THREADS.store(self.threads, Ordering::Relaxed);
    }

    /// True when this configuration selects the serial code path.
    pub fn is_serial(&self) -> bool {
        self.threads <= 1
    }

    /// Split `0..n` into at most `threads` contiguous ranges of
    /// near-equal length (deterministic in `n` and `threads` only).
    /// Empty for `n == 0`.
    pub fn chunk_ranges(&self, n: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        let k = self.threads.max(1).min(n);
        let base = n / k;
        let extra = n % k;
        let mut out = Vec::with_capacity(k);
        let mut start = 0usize;
        for i in 0..k {
            let len = base + usize::from(i < extra);
            out.push(start..start + len);
            start += len;
        }
        out
    }

    /// Split `0..cum.len()-1` into at most `threads` contiguous ranges
    /// balanced by a cumulative weight vector (`cum[i]` = total weight
    /// of items `0..i`, e.g. a CSR `indptr`). Deterministic in `cum`
    /// and `threads` only. Empty when there are no items.
    pub fn chunk_ranges_weighted(&self, cum: &[usize]) -> Vec<Range<usize>> {
        let n = cum.len().saturating_sub(1);
        if n == 0 {
            return Vec::new();
        }
        let total = cum[n];
        if total == 0 {
            return self.chunk_ranges(n);
        }
        let k = self.threads.max(1).min(n);
        let mut out = Vec::with_capacity(k);
        let mut start = 0usize;
        for i in 1..=k {
            if start == n {
                break;
            }
            let end = if i == k {
                n
            } else {
                let target = ((total as u128 * i as u128) / k as u128) as usize;
                cum.partition_point(|&c| c < target).clamp(start + 1, n)
            };
            out.push(start..end);
            start = end;
        }
        out
    }
}

/// The process-wide compute pool, created on first use and sized to the
/// available cores. Shared by every parallel kernel; chunk counts (not
/// worker counts) control per-op parallelism, so a smaller
/// [`Parallelism`] simply submits fewer, larger jobs.
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::default_size)
}

/// Run `f` over each range on the global pool, returning results **in
/// range order**. Falls back to inline execution for 0 or 1 ranges.
///
/// A panic inside `f` is re-raised on the caller with its original
/// payload (the remaining chunks still run to completion first — the
/// pool's workers catch unwinds).
///
/// Kernel jobs must be pure compute: a job that itself blocks on the
/// pool (submits and joins) could deadlock a saturated pool, so the
/// parallel kernels never nest.
pub fn parallel_map_ranges<R, F>(ranges: Vec<Range<usize>>, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(Range<usize>) -> R + Sync,
{
    if ranges.len() <= 1 {
        return ranges.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<std::thread::Result<R>>> = ranges.iter().map(|_| None).collect();
    {
        let f = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .zip(ranges)
            .map(|(slot, range)| {
                Box::new(move || {
                    *slot = Some(std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        move || f(range),
                    )));
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        global_pool().run_scoped(jobs);
    }
    slots
        .into_iter()
        .map(|s| match s.expect("batch job ran to completion") {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for threads in [1, 2, 3, 7, 16] {
            for n in [0usize, 1, 2, 7, 100, 101] {
                let ranges = Parallelism::with_threads(threads).chunk_ranges(n);
                let mut next = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, next, "contiguous");
                    assert!(r.end > r.start, "non-empty chunk");
                    next = r.end;
                }
                assert_eq!(next, n, "covers 0..{n} at {threads} threads");
                assert!(ranges.len() <= threads.max(1));
            }
        }
    }

    #[test]
    fn chunk_ranges_weighted_cover_and_balance() {
        // Heavily skewed weights: all mass in the last item.
        let cum = vec![0usize, 0, 0, 0, 100];
        let ranges = Parallelism::with_threads(4).chunk_ranges_weighted(&cum);
        let mut next = 0usize;
        for r in &ranges {
            assert_eq!(r.start, next);
            assert!(r.end > r.start);
            next = r.end;
        }
        assert_eq!(next, 4);
        // Uniform weights split evenly.
        let cum: Vec<usize> = (0..=8).map(|i| i * 10).collect();
        let ranges = Parallelism::with_threads(2).chunk_ranges_weighted(&cum);
        assert_eq!(ranges, vec![0..4, 4..8]);
        // Zero total weight falls back to count-based chunks.
        let ranges = Parallelism::with_threads(2).chunk_ranges_weighted(&[0, 0, 0]);
        assert_eq!(ranges, vec![0..1, 1..2]);
        // No items.
        assert!(Parallelism::with_threads(4).chunk_ranges_weighted(&[0]).is_empty());
        assert!(Parallelism::with_threads(4).chunk_ranges_weighted(&[]).is_empty());
    }

    #[test]
    fn parallel_map_ranges_orders_results() {
        let ranges = Parallelism::with_threads(4).chunk_ranges(1000);
        let sums = parallel_map_ranges(ranges.clone(), |r| r.sum::<usize>());
        assert_eq!(sums.len(), ranges.len());
        assert_eq!(sums.iter().sum::<usize>(), (0..1000).sum::<usize>());
        // Results line up with their ranges, not with completion order.
        for (r, s) in ranges.into_iter().zip(&sums) {
            assert_eq!(*s, r.sum::<usize>());
        }
    }

    #[test]
    fn kernel_panic_propagates_with_payload() {
        let result = std::panic::catch_unwind(|| {
            let ranges = Parallelism::with_threads(4).chunk_ranges(100);
            parallel_map_ranges(ranges, |r| {
                if r.contains(&50) {
                    panic!("chunk failure at 50");
                }
                r.len()
            })
        });
        let payload = result.expect_err("must propagate the chunk panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("chunk failure at 50"), "payload preserved, got {msg:?}");
    }

    #[test]
    fn serial_flag_and_defaults() {
        assert!(Parallelism::serial().is_serial());
        assert!(!Parallelism::with_threads(4).is_serial());
        assert_eq!(Parallelism::with_threads(0).threads, 1);
        assert!(Parallelism::current().threads >= 1);
    }

    #[test]
    fn env_threads_is_cached_and_below_default_in_precedence() {
        // The cached env read is stable across calls.
        assert_eq!(Parallelism::env_threads(), Parallelism::env_threads());
        // An installed process default beats the environment…
        Parallelism::with_threads(3).set_default();
        assert_eq!(Parallelism::current().threads, 3);
        // …and clearing it falls back to D4M_THREADS, then auto.
        Parallelism { threads: 0 }.set_default();
        let cur = Parallelism::current().threads;
        match Parallelism::env_threads() {
            Some(n) => assert_eq!(cur, n),
            None => assert!(cur >= 1),
        }
    }
}
