//! A small fixed-size thread pool over `std::thread`.
//!
//! Used by the parallel compute kernels (via
//! [`crate::util::parallel::global_pool`]), the parallel store scanner,
//! and available to the ingest pipeline. Jobs are `FnOnce` closures;
//! `join` blocks until all submitted jobs complete, and
//! [`ThreadPool::run_scoped`] extends that to borrowing (non-`'static`)
//! jobs for fork-join kernels. Backpressure between pipeline stages is
//! *not* handled here — that is the bounded channels in
//! [`crate::pipeline`] — the pool is purely a worker-thread reuse
//! mechanism.
//!
//! A job that panics does not poison the pool: the worker catches the
//! unwind, counts it in [`ThreadPool::jobs_panicked`], and keeps
//! serving, so `join` always returns.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
    panicked: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n >= 1`).
    ///
    /// The internal job queue is bounded at `4 * n` so a producer that
    /// outruns the workers blocks in [`ThreadPool::execute`] rather than
    /// growing memory without bound.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = sync_channel::<Job>(4 * n);
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                let executed = Arc::clone(&executed);
                let panicked = Arc::clone(&panicked);
                std::thread::Builder::new()
                    .name(format!("d4m-pool-{i}"))
                    .spawn(move || worker_loop(&rx, &in_flight, &executed, &panicked))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight, executed, panicked }
    }

    /// Pool sized to available parallelism (at least 2).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.max(2))
    }

    /// Submit a job; blocks if the queue is full.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.in_flight;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool workers exited early");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cvar) = &*self.in_flight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
    }

    /// Total number of jobs executed so far.
    pub fn jobs_executed(&self) -> usize {
        self.executed.load(Ordering::Relaxed)
    }

    /// Number of executed jobs that panicked (caught, not fatal).
    pub fn jobs_panicked(&self) -> usize {
        self.panicked.load(Ordering::Relaxed)
    }

    /// Submit a batch of *borrowing* jobs and block until every job in
    /// **this batch** has finished — the fork-join primitive behind the
    /// parallel compute kernels. Completion is tracked per batch, so
    /// concurrent `run_scoped` callers (or unrelated `execute` jobs) on
    /// the shared pool never stall each other's return.
    ///
    /// Unlike [`ThreadPool::execute`], jobs need not be `'static`: they
    /// may borrow from the caller's stack, which is safe because this
    /// method does not return until every batch job has run to
    /// completion (a panicking job still counts as complete — the
    /// batch counter is decremented by a drop guard that runs during
    /// unwinding — its output is simply never produced).
    ///
    /// Jobs must not themselves submit to (and wait on) this pool:
    /// nested fork-join on a saturated pool can deadlock.
    pub fn run_scoped<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        /// Decrements the batch counter on drop — also during unwind.
        struct BatchGuard(Arc<(Mutex<usize>, Condvar)>);
        impl Drop for BatchGuard {
            fn drop(&mut self) {
                let (lock, cvar) = &*self.0;
                let mut n = lock.lock().unwrap();
                *n -= 1;
                if *n == 0 {
                    cvar.notify_all();
                }
            }
        }

        let batch = Arc::new((Mutex::new(jobs.len()), Condvar::new()));
        for job in jobs {
            let guard = BatchGuard(Arc::clone(&batch));
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let _guard = guard;
                job();
            });
            // SAFETY: the transmute only erases the `'env` lifetime
            // bound. The wait below blocks until this batch's counter
            // reaches zero, and every job decrements it exactly once
            // (via the drop guard, even on panic — worker_loop catches
            // the unwind), so no job can outlive the borrows it
            // captures.
            let wrapped: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(wrapped) };
            self.execute(wrapped);
        }
        let (lock, cvar) = &*batch;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Job>>,
    in_flight: &(Mutex<usize>, Condvar),
    executed: &AtomicUsize,
    panicked: &AtomicUsize,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(job) => {
                // Catch panics so one bad job can't wedge `join` (the
                // in-flight count must reach zero even on unwind).
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                executed.fetch_add(1, Ordering::Relaxed);
                if outcome.is_err() {
                    panicked.fetch_add(1, Ordering::Relaxed);
                }
                let (lock, cvar) = in_flight;
                let mut n = lock.lock().unwrap();
                *n -= 1;
                if *n == 0 {
                    cvar.notify_all();
                }
            }
            Err(_) => return, // channel closed: shut down
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        drop(self.tx.take()); // close channel so workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 1..=100u64 {
            let sum = Arc::clone(&sum);
            pool.execute(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(sum.load(Ordering::Relaxed), 5050);
        assert_eq!(pool.jobs_executed(), 100);
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn join_can_be_called_repeatedly() {
        let pool = ThreadPool::new(2);
        for round in 0..3 {
            let counter = Arc::new(AtomicU64::new(0));
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::Relaxed), 10, "round {round}");
        }
    }

    #[test]
    fn drop_waits_for_completion() {
        let done = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..8 {
                let d = Arc::clone(&done);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    d.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop
        assert_eq!(done.load(Ordering::Relaxed), 8);
    }

    #[test]
    #[should_panic]
    fn zero_workers_panics() {
        let _ = ThreadPool::new(0);
    }

    #[test]
    fn run_scoped_borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let input: Vec<u64> = (0..100).collect();
        let mut partials = [0u64; 4];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = partials
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    let chunk = &input[i * 25..(i + 1) * 25];
                    Box::new(move || *slot = chunk.iter().sum()) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(partials.iter().sum::<u64>(), 4950);
    }

    #[test]
    fn panicking_job_does_not_wedge_join() {
        let pool = ThreadPool::new(2);
        let ok = Arc::new(AtomicU64::new(0));
        for i in 0..10 {
            let ok = Arc::clone(&ok);
            pool.execute(move || {
                if i == 3 {
                    panic!("injected failure");
                }
                ok.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join(); // must return despite the panic
        assert_eq!(ok.load(Ordering::Relaxed), 9);
        assert_eq!(pool.jobs_panicked(), 1);
        assert_eq!(pool.jobs_executed(), 10);
        // The pool still works after a panic.
        let again = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let a = Arc::clone(&again);
            pool.execute(move || {
                a.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(again.load(Ordering::Relaxed), 5);
    }
}
