//! A small fixed-size thread pool over `std::thread`.
//!
//! Used by the ingest pipeline and the parallel store scanner. Jobs are
//! `FnOnce` closures; `join` blocks until all submitted jobs complete.
//! Backpressure between pipeline stages is *not* handled here — that is
//! the bounded channels in [`crate::pipeline`] — the pool is purely a
//! worker-thread reuse mechanism.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n >= 1`).
    ///
    /// The internal job queue is bounded at `4 * n` so a producer that
    /// outruns the workers blocks in [`ThreadPool::execute`] rather than
    /// growing memory without bound.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = sync_channel::<Job>(4 * n);
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                let executed = Arc::clone(&executed);
                std::thread::Builder::new()
                    .name(format!("d4m-pool-{i}"))
                    .spawn(move || worker_loop(&rx, &in_flight, &executed))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight, executed }
    }

    /// Pool sized to available parallelism (at least 2).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.max(2))
    }

    /// Submit a job; blocks if the queue is full.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.in_flight;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool workers exited early");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cvar) = &*self.in_flight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
    }

    /// Total number of jobs executed so far.
    pub fn jobs_executed(&self) -> usize {
        self.executed.load(Ordering::Relaxed)
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Job>>,
    in_flight: &(Mutex<usize>, Condvar),
    executed: &AtomicUsize,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(job) => {
                job();
                executed.fetch_add(1, Ordering::Relaxed);
                let (lock, cvar) = in_flight;
                let mut n = lock.lock().unwrap();
                *n -= 1;
                if *n == 0 {
                    cvar.notify_all();
                }
            }
            Err(_) => return, // channel closed: shut down
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
        drop(self.tx.take()); // close channel so workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 1..=100u64 {
            let sum = Arc::clone(&sum);
            pool.execute(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(sum.load(Ordering::Relaxed), 5050);
        assert_eq!(pool.jobs_executed(), 100);
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn join_can_be_called_repeatedly() {
        let pool = ThreadPool::new(2);
        for round in 0..3 {
            let counter = Arc::new(AtomicU64::new(0));
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.join();
            assert_eq!(counter.load(Ordering::Relaxed), 10, "round {round}");
        }
    }

    #[test]
    fn drop_waits_for_completion() {
        let done = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..8 {
                let d = Arc::clone(&done);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    d.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop
        assert_eq!(done.load(Ordering::Relaxed), 8);
    }

    #[test]
    #[should_panic]
    fn zero_workers_panics() {
        let _ = ThreadPool::new(0);
    }
}
