//! Human-readable formatting for benchmark / example output.

/// Format a count with thousands separators: `1234567` → `"1,234,567"`.
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// Format seconds adaptively: `0.0000123` → `"12.30µs"`, `1.5` → `"1.500s"`.
pub fn seconds(s: f64) -> String {
    if s < 0.0 || !s.is_finite() {
        return format!("{s}");
    }
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Format bytes adaptively with binary units.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

/// Format an operations-per-second rate.
pub fn rate(ops: f64) -> String {
    if ops >= 1e9 {
        format!("{:.2}G/s", ops / 1e9)
    } else if ops >= 1e6 {
        format!("{:.2}M/s", ops / 1e6)
    } else if ops >= 1e3 {
        format!("{:.2}K/s", ops / 1e3)
    } else {
        format!("{ops:.1}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(1234567), "1,234,567");
    }

    #[test]
    fn seconds_units() {
        assert_eq!(seconds(2.5), "2.500s");
        assert_eq!(seconds(0.0025), "2.500ms");
        assert_eq!(seconds(12.3e-6), "12.30µs");
        assert_eq!(seconds(5e-9), "5.0ns");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(2048), "2.00KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00MiB");
    }

    #[test]
    fn rate_units() {
        assert_eq!(rate(500.0), "500.0/s");
        assert_eq!(rate(2_500_000.0), "2.50M/s");
        assert_eq!(rate(3.2e9), "3.20G/s");
    }
}
