//! Small self-contained utilities shared across the crate.
//!
//! The build environment is fully offline with a minimal vendored crate
//! set (no `rand`, `clap`, `rayon`, `criterion`, `proptest`), so this
//! module provides in-repo equivalents:
//!
//! * [`prng`] — a seedable SplitMix64 PRNG (workloads, property tests).
//! * [`timer`] — wall-clock timing helpers with robust repeat-averaging.
//! * [`args`] — a tiny `--flag value` command-line parser.
//! * [`pool`] — a scoped thread pool over `std::thread`.
//! * [`parallel`] — the [`parallel::Parallelism`] knob plus the
//!   deterministic fork-join helpers used by the parallel compute
//!   kernels (SpGEMM, constructor key sort, tablet scans).
//! * [`prop`] — a miniature property-based testing harness with
//!   random case generation and failure reporting.
//! * [`retry`] — the storage error taxonomy
//!   (transient/permanent classification) and a deterministic
//!   seeded-jitter retry-with-backoff policy.
//! * [`human`] — human-readable formatting for counts, bytes, seconds.
//! * [`json`] — minimal JSON emission for machine-readable artifacts
//!   (the benchmark trajectory files).
//! * [`intern`] — [`intern::SharedStr`] shared-bytes strings and the
//!   [`intern::StrDict`] dense string dictionary (the PR 4 key
//!   encoding), plus the fast Fx-style hasher they ride on.

pub mod args;
pub mod human;
pub mod intern;
pub mod json;
pub mod parallel;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod retry;
pub mod timer;

pub use args::Args;
pub use intern::{SharedStr, StrDict};
pub use json::Json;
pub use parallel::Parallelism;
pub use pool::ThreadPool;
pub use prng::SplitMix64;
pub use retry::{ErrorClass, RetryPolicy};
pub use timer::{time_op, Stopwatch, Timings};
