//! Minimal JSON emission (the offline build has no `serde`): enough to
//! write the machine-readable benchmark trajectory (`BENCH_PR2.json`)
//! and future structured artifacts. Output is deterministic — object
//! fields render in insertion order.

/// A JSON value tree. Build with the variant constructors (or the
/// [`Json::str`] convenience) and serialize with [`Json::render`].
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number. Non-finite values (JSON has no NaN/∞) render as `null`.
    Num(f64),
    /// A string (escaped on render).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object as ordered `(key, value)` pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// String value from anything string-like.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize to compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                escape_into(s, out);
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    escape_into(k, out);
                    out.push_str("\":");
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Num(3.5).render(), "3.5");
        assert_eq!(Json::Num(12.0).render(), "12");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::str("hi").render(), "\"hi\"");
    }

    #[test]
    fn strings_escape() {
        assert_eq!(Json::str("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::str("\u{1}").render(), "\"\\u0001\"");
    }

    #[test]
    fn nested_structure() {
        let doc = Json::Obj(vec![
            ("op".into(), Json::str("matmul")),
            ("threads".into(), Json::Num(4.0)),
            ("tags".into(), Json::Arr(vec![Json::str("a"), Json::str("b")])),
        ]);
        assert_eq!(doc.render(), r#"{"op":"matmul","threads":4,"tags":["a","b"]}"#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(Vec::new()).render(), "[]");
        assert_eq!(Json::Obj(Vec::new()).render(), "{}");
    }
}
