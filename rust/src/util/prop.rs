//! Miniature property-based testing harness.
//!
//! `proptest` is unavailable offline; this provides the subset the test
//! suite needs: run a property over many randomly generated cases with a
//! fixed seed (reproducible), report the first failing case's seed and
//! index so it can be replayed, and provide generators for the key/value
//! shapes D4M cares about (triple lists, sorted unique key vectors, ...).
//!
//! Usage:
//! ```
//! use d4m::util::prop::{check, Gen};
//! check("addition commutes", 200, |g| {
//!     let a = g.rng().range_i64(-100, 100);
//!     let b = g.rng().range_i64(-100, 100);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::SplitMix64;

/// Per-case generation context handed to properties.
pub struct Gen {
    rng: SplitMix64,
    /// Case index (0-based) within the `check` run.
    pub case: usize,
}

impl Gen {
    /// The case's deterministic PRNG.
    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }

    /// A vector of length in `[0, max_len]` filled by `f`.
    pub fn vec_of<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut SplitMix64) -> T) -> Vec<T> {
        let len = self.rng.below_usize(max_len + 1);
        (0..len).map(|_| f(&mut self.rng)).collect()
    }

    /// Random "D4M-ish" key string: small integer rendered as a string.
    pub fn key_string(&mut self, universe: u64) -> String {
        self.rng.below(universe.max(1)).to_string()
    }

    /// Sorted, deduplicated vector of random key strings.
    pub fn sorted_unique_keys(&mut self, max_len: usize, universe: u64) -> Vec<String> {
        let mut v = self.vec_of(max_len, |r| r.below(universe.max(1)).to_string());
        v.sort();
        v.dedup();
        v
    }

    /// Random triple list `(row, col, val)` over a small key universe, so
    /// collisions (duplicate (row, col)) actually occur.
    pub fn triples(
        &mut self,
        max_len: usize,
        universe: u64,
    ) -> (Vec<String>, Vec<String>, Vec<f64>) {
        let len = self.rng.below_usize(max_len + 1);
        let mut rows = Vec::with_capacity(len);
        let mut cols = Vec::with_capacity(len);
        let mut vals = Vec::with_capacity(len);
        for _ in 0..len {
            rows.push(self.rng.below(universe.max(1)).to_string());
            cols.push(self.rng.below(universe.max(1)).to_string());
            vals.push(self.rng.range_i64(1, 100) as f64);
        }
        (rows, cols, vals)
    }
}

/// Default seed for property runs. Override with `D4M_PROP_SEED` env var
/// to replay a reported failure.
fn base_seed() -> u64 {
    std::env::var("D4M_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD4A7_2022)
}

/// Run `prop` over `cases` generated cases. Panics (with the case seed)
/// on the first failure; the property signals failure by panicking.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let seed = base_seed();
    let mut root = SplitMix64::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen { rng: SplitMix64::new(case_seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (replay with D4M_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("tautology", 50, |g| {
            let x = g.rng().next_u64();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn failing_property_reports() {
        check("always-fails", 10, |_| panic!("nope"));
    }

    #[test]
    fn generators_are_deterministic_per_run() {
        let mut first: Vec<Vec<String>> = Vec::new();
        check("collect", 5, |g| {
            first.push(g.sorted_unique_keys(10, 8));
        });
        let mut second: Vec<Vec<String>> = Vec::new();
        check("collect", 5, |g| {
            second.push(g.sorted_unique_keys(10, 8));
        });
        assert_eq!(first, second);
    }

    #[test]
    fn sorted_unique_keys_invariants() {
        check("sorted-unique", 100, |g| {
            let keys = g.sorted_unique_keys(32, 16);
            for w in keys.windows(2) {
                assert!(w[0] < w[1], "not strictly sorted: {keys:?}");
            }
        });
    }

    #[test]
    fn triples_have_matching_lengths() {
        check("triple-lengths", 50, |g| {
            let (r, c, v) = g.triples(64, 10);
            assert_eq!(r.len(), c.len());
            assert_eq!(c.len(), v.len());
        });
    }
}
