//! Shared-bytes strings and string interning — the dictionary-encoded
//! key space of PR 4.
//!
//! D4M's performance story is *encode once*: map string keys onto dense
//! integer indices at the boundary, then run every kernel on integers
//! (the Julia D4M paper, arXiv:1608.04041, credits its constructor wins
//! to exactly this; D4M 3.0, arXiv:1702.03253, pushes the dictionary
//! into the server). This module supplies the two primitives the rest
//! of the crate builds that on:
//!
//! * [`SharedStr`] — an `Arc<str>`-backed immutable string, the cell
//!   representation of the triple store. Cloning is a pointer copy
//!   (one atomic increment), so a cell can flow from the tablet
//!   `BTreeMap` through every scan stage and into the compute kernels
//!   without its bytes ever being copied.
//! * [`StrDict`] — a dense `str ↔ u32` dictionary with an
//!   order-preserving finalize ([`StrDict::into_sorted`]): intern every
//!   occurrence, touch the bytes once per *distinct* key, and recover
//!   the canonical sorted-unique key list plus an `id → rank` map at
//!   the end.
//!
//! Hashing uses [`FxHasher64`], a Fx-style multiply-xor hasher —
//! interning sits on the per-cell ingest path, where SipHash's
//! per-byte cost is measurable. The dictionary is not exposed to
//! untrusted inputs, so HashDoS resistance is not a concern here.
//!
//! PR 6 spills this dictionary into the storage layer: an immutable
//! sorted run ([`crate::store::Run`]) is built by interning a frozen
//! memtable's rows, columns, and values through one [`StrDict`], so a
//! run on disk is a string pool plus `u32` id triples — the on-disk
//! shape of the same encode-once idea.

use std::borrow::Borrow;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::fmt;
use std::hash::{BuildHasherDefault, Hash, Hasher};
use std::ops::Deref;
use std::sync::Arc;

/// An immutable, cheaply clonable string: `Arc<str>` with string-like
/// ergonomics. Equality, ordering, and hashing all delegate to the
/// underlying bytes, so `SharedStr` is a drop-in key for sorted and
/// hashed containers (and `Borrow<str>` makes `&str` lookups work).
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SharedStr(Arc<str>);

impl SharedStr {
    /// View as `&str`.
    #[inline]
    pub fn as_str(&self) -> &str {
        &self.0
    }

    /// Whether two handles share one allocation (diagnostics only —
    /// equal content in distinct allocations compares equal).
    pub fn ptr_eq(&self, other: &SharedStr) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }
}

impl Deref for SharedStr {
    type Target = str;

    #[inline]
    fn deref(&self) -> &str {
        &self.0
    }
}

impl Borrow<str> for SharedStr {
    #[inline]
    fn borrow(&self) -> &str {
        &self.0
    }
}

impl AsRef<str> for SharedStr {
    #[inline]
    fn as_ref(&self) -> &str {
        &self.0
    }
}

impl From<&str> for SharedStr {
    fn from(s: &str) -> SharedStr {
        SharedStr(Arc::from(s))
    }
}

impl From<String> for SharedStr {
    fn from(s: String) -> SharedStr {
        SharedStr(Arc::from(s))
    }
}

impl From<&String> for SharedStr {
    fn from(s: &String) -> SharedStr {
        SharedStr(Arc::from(s.as_str()))
    }
}

impl From<Box<str>> for SharedStr {
    fn from(s: Box<str>) -> SharedStr {
        SharedStr(Arc::from(s))
    }
}

impl From<Arc<str>> for SharedStr {
    fn from(s: Arc<str>) -> SharedStr {
        SharedStr(s)
    }
}

impl From<&SharedStr> for SharedStr {
    fn from(s: &SharedStr) -> SharedStr {
        s.clone()
    }
}

impl PartialEq<str> for SharedStr {
    fn eq(&self, other: &str) -> bool {
        self.as_str() == other
    }
}

impl PartialEq<&str> for SharedStr {
    fn eq(&self, other: &&str) -> bool {
        self.as_str() == *other
    }
}

impl PartialEq<String> for SharedStr {
    fn eq(&self, other: &String) -> bool {
        self.as_str() == other.as_str()
    }
}

impl PartialEq<SharedStr> for str {
    fn eq(&self, other: &SharedStr) -> bool {
        self == other.as_str()
    }
}

impl PartialEq<SharedStr> for &str {
    fn eq(&self, other: &SharedStr) -> bool {
        *self == other.as_str()
    }
}

impl PartialEq<SharedStr> for String {
    fn eq(&self, other: &SharedStr) -> bool {
        self.as_str() == other.as_str()
    }
}

impl fmt::Display for SharedStr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self.as_str(), f)
    }
}

impl fmt::Debug for SharedStr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.as_str(), f)
    }
}

/// Fx-style 64-bit hasher (the rustc-hash recipe): fold each 8-byte
/// word with a rotate-xor-multiply round. Several times faster than the
/// default SipHash on short keys, which matters because interning runs
/// once per *cell* on the ingest paths.
#[derive(Default)]
pub struct FxHasher64 {
    hash: u64,
}

/// The multiplicative constant of the Fx round (golden-ratio based).
const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher64 {
    #[inline]
    fn round(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher64 {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.round(u64::from_le_bytes(c.try_into().expect("8-byte chunk")));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            // Fold the tail length in so "a" and "a\0" differ.
            tail[7] = rest.len() as u8;
            self.round(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.round(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.round(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.round(v as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `BuildHasher` for [`FxHasher64`]-keyed maps.
pub type FxBuild = BuildHasherDefault<FxHasher64>;

/// Hash anything with the crate's Fx hasher — the dictionary's probe
/// key. (`SharedStr` and `&str` hash identically because `SharedStr`'s
/// `Hash` delegates to the underlying `str`.)
fn fx_hash<Q: Hash + ?Sized>(q: &Q) -> u64 {
    let mut h = FxHasher64::default();
    q.hash(&mut h);
    h.finish()
}

/// Order the positions of `items` by byte-lexicographic string order
/// with the digest-pair trick shared by every string sort in the crate
/// ([`StrDict::into_sorted`] here, `sort_dedup_strs` in
/// `sorted::keysort`): tag each string with its first 8 bytes
/// (big-endian, zero-padded) and sort the `(digest, index)` pairs.
/// When every digest is *exact* — the string fits the prefix **and**
/// has no trailing NUL (zero padding would make `"abc"` and `"abc\0"`
/// digest-equal) — the sort is pure `u64` compares; otherwise digest
/// ties fall back to a full compare. Returns the sorted pairs plus the
/// exactness flag (exact digests ⇒ digest equality *is* string
/// equality, which the dedup in `sorted::keysort` exploits). Keeping
/// this in one place keeps the exactness invariant from drifting
/// between copies.
pub(crate) fn digest_sort_strs<S: AsRef<str>>(items: &[S]) -> (Vec<(u64, u32)>, bool) {
    let mut tagged: Vec<(u64, u32)> = Vec::with_capacity(items.len());
    let mut all_exact = true;
    for (i, s) in items.iter().enumerate() {
        let b = s.as_ref().as_bytes();
        let mut p = [0u8; 8];
        let m = b.len().min(8);
        p[..m].copy_from_slice(&b[..m]);
        all_exact &= b.len() <= 8 && b.last() != Some(&0);
        tagged.push((u64::from_be_bytes(p), i as u32));
    }
    if all_exact {
        tagged.sort_unstable();
    } else {
        tagged.sort_unstable_by(|a, b| {
            a.0.cmp(&b.0)
                .then_with(|| items[a.1 as usize].as_ref().cmp(items[b.1 as usize].as_ref()))
        });
    }
    (tagged, all_exact)
}

/// A dense dictionary over any hashable key: first-appearance order
/// `u32` ids with clone-once interning — the single home for the
/// intern machinery behind both [`StrDict`] (shared-bytes scan keys)
/// and [`crate::sorted::KeyDict`] (mixed numeric/string [`Key`]s in
/// the constructor).
///
/// Interning an already-known key is a hash probe; interning a new one
/// clones the key **exactly once** — `keys` is the sole owner, and the
/// probe index maps the key's 64-bit Fx hash to its id (the
/// vanishingly rare genuine hash collisions overflow into a linear
/// list, so correctness never rests on hash uniqueness). A one-entry
/// "last id" cache makes runs of equal keys (sorted scan streams group
/// cells by row) skip the hash entirely.
///
/// [`Key`]: crate::assoc::Key
pub struct Dict<K> {
    keys: Vec<K>,
    /// Key hash → id of the first key interned with that hash.
    map: HashMap<u64, u32, FxBuild>,
    /// Ids whose hash collided with an earlier, different key.
    overflow: Vec<u32>,
    last: u32,
}

impl<K> Default for Dict<K> {
    fn default() -> Self {
        Dict::new()
    }
}

impl<K> Dict<K> {
    /// Empty dictionary.
    pub fn new() -> Dict<K> {
        Dict { keys: Vec::new(), map: HashMap::default(), overflow: Vec::new(), last: u32::MAX }
    }

    /// Empty dictionary expecting about `n` distinct keys.
    pub fn with_capacity(n: usize) -> Dict<K> {
        Dict {
            keys: Vec::with_capacity(n),
            map: HashMap::with_capacity_and_hasher(n, FxBuild::default()),
            overflow: Vec::new(),
            last: u32::MAX,
        }
    }

    /// Number of distinct keys interned.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when nothing has been interned.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// The key for `id` (ids are dense: `0..len`).
    pub fn get(&self, id: u32) -> &K {
        &self.keys[id as usize]
    }

    /// The distinct keys in first-appearance order (the id space).
    pub fn keys(&self) -> &[K] {
        &self.keys
    }

    /// Consume the dictionary into its distinct keys (first-appearance
    /// order).
    pub fn into_keys(self) -> Vec<K> {
        self.keys
    }

    /// The shared probe: find the id whose key satisfies `eq` under
    /// hash `h`, or assign the next dense id to `make()`.
    fn lookup_or_insert(
        &mut self,
        h: u64,
        eq: impl Fn(&K) -> bool,
        make: impl FnOnce() -> K,
    ) -> u32 {
        match self.map.entry(h) {
            Entry::Vacant(v) => {
                let id = self.keys.len() as u32;
                self.keys.push(make());
                v.insert(id);
                id
            }
            Entry::Occupied(o) => {
                let id0 = *o.get();
                if eq(&self.keys[id0 as usize]) {
                    return id0;
                }
                // A genuine 64-bit hash collision: keep correctness
                // with a linear overflow list (its length is the
                // number of collisions ever seen — effectively zero).
                if let Some(&id) =
                    self.overflow.iter().find(|&&id| eq(&self.keys[id as usize]))
                {
                    return id;
                }
                let id = self.keys.len() as u32;
                self.keys.push(make());
                self.overflow.push(id);
                id
            }
        }
    }
}

impl<K: Hash + Eq + Clone> Dict<K> {
    /// Intern a key: its dense id, assigned (and the key cloned, once)
    /// on first sight.
    pub fn intern(&mut self, k: &K) -> u32 {
        if let Some(prev) = self.keys.get(self.last as usize) {
            if prev == k {
                return self.last;
            }
        }
        let id = self.lookup_or_insert(fx_hash(k), |key| key == k, || k.clone());
        self.last = id;
        id
    }
}

/// A dense string dictionary: [`Dict`] over shared-bytes keys, so
/// interning never copies string bytes (new keys are pointer clones),
/// plus `&str` lookups and an order-preserving finalize.
pub type StrDict = Dict<SharedStr>;

impl Dict<SharedStr> {
    /// Intern by `&str` — allocates a [`SharedStr`] only for keys not
    /// seen before (`&str` and `SharedStr` hash identically, so both
    /// intern forms address one probe index).
    pub fn intern_str(&mut self, s: &str) -> u32 {
        if let Some(prev) = self.keys.get(self.last as usize) {
            if prev == s {
                return self.last;
            }
        }
        let id = self.lookup_or_insert(fx_hash(s), |key| key == s, || SharedStr::from(s));
        self.last = id;
        id
    }

    /// Order-preserving finalize: `(sorted_keys, rank)` where
    /// `sorted_keys` is the canonical sorted-unique key list and
    /// `rank[id]` is the position of key `id` in it. When keys were
    /// interned in sorted order (a sorted scan stream's row keys), the
    /// sort is skipped entirely; otherwise the shared digest-pair sort
    /// orders the (distinct) keys.
    ///
    /// After the remap, comparing two ranks *is* comparing the two
    /// keys' bytes (`rank[a] < rank[b] ⟺ key(a) < key(b)`), so a cell
    /// block already sorted by its string keys stays sorted as rank
    /// tuples — the property [`crate::store::Run`] relies on to
    /// dictionary-encode a frozen memtable without re-sorting it.
    pub fn into_sorted(self) -> (Vec<SharedStr>, Vec<u32>) {
        let n = self.keys.len();
        if self.keys.windows(2).all(|w| w[0] < w[1]) {
            return (self.keys, (0..n as u32).collect());
        }
        let (tagged, _) = digest_sort_strs(&self.keys);
        let mut rank = vec![0u32; n];
        let mut sorted = Vec::with_capacity(n);
        for (pos, &(_, id)) in tagged.iter().enumerate() {
            rank[id as usize] = pos as u32;
            sorted.push(self.keys[id as usize].clone());
        }
        (sorted, rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_str_basics() {
        let a = SharedStr::from("hello");
        let b = a.clone();
        assert!(a.ptr_eq(&b));
        assert_eq!(a, b);
        assert_eq!(a, "hello");
        assert_eq!("hello", a);
        assert_eq!(a, "hello".to_string());
        assert_eq!(a.as_str(), "hello");
        assert_eq!(a.len(), 5); // str methods via Deref
        assert!(a < SharedStr::from("world"));
        let c = SharedStr::from("hello".to_string());
        assert_eq!(a, c);
        assert!(!a.ptr_eq(&c));
        assert_eq!(format!("{a}"), "hello");
        assert_eq!(format!("{a:?}"), "\"hello\"");
    }

    #[test]
    fn shared_str_hash_matches_str_for_borrow() {
        // Borrow<str> contract: hash(SharedStr) == hash(its str).
        use std::hash::{BuildHasher, Hash, Hasher};
        let bh = std::collections::hash_map::RandomState::new();
        let shared = SharedStr::from("abc");
        let mut h1 = bh.build_hasher();
        shared.hash(&mut h1);
        let mut h2 = bh.build_hasher();
        "abc".hash(&mut h2);
        assert_eq!(h1.finish(), h2.finish());
        // And the practical consequence: &str lookups in hashed maps.
        let mut m: HashMap<SharedStr, i32> = HashMap::new();
        m.insert(SharedStr::from("k"), 7);
        assert_eq!(m.get("k"), Some(&7));
    }

    #[test]
    fn fx_hasher_distinguishes_lengths_and_content() {
        let h = |b: &[u8]| {
            let mut s = FxHasher64::default();
            s.write(b);
            s.finish()
        };
        assert_ne!(h(b"a"), h(b"b"));
        assert_ne!(h(b"a"), h(b"a\0"));
        assert_ne!(h(b"12345678"), h(b"123456789"));
        assert_eq!(h(b"same-bytes"), h(b"same-bytes"));
    }

    #[test]
    fn dict_assigns_dense_first_appearance_ids() {
        let mut d = StrDict::new();
        let b = SharedStr::from("b");
        let a = SharedStr::from("a");
        assert_eq!(d.intern(&b), 0);
        assert_eq!(d.intern(&a), 1);
        assert_eq!(d.intern(&b), 0);
        assert_eq!(d.intern_str("a"), 1);
        assert_eq!(d.intern_str("c"), 2);
        assert_eq!(d.len(), 3);
        assert_eq!(d.get(0), &b);
        // Interning shares bytes with the first occurrence.
        assert!(d.get(0).ptr_eq(&b));
    }

    #[test]
    fn dict_run_cache_hits_equal_runs() {
        let mut d = StrDict::new();
        let r = SharedStr::from("row1");
        for _ in 0..5 {
            assert_eq!(d.intern(&r), 0);
        }
        assert_eq!(d.intern_str("row2"), 1);
        assert_eq!(d.intern_str("row2"), 1);
        assert_eq!(d.intern(&r), 0);
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn into_sorted_is_order_preserving() {
        let mut d = StrDict::new();
        for s in ["m", "a", "zz", "a", "k", "m"] {
            d.intern_str(s);
        }
        // ids: m=0, a=1, zz=2, k=3
        let (sorted, rank) = d.into_sorted();
        let got: Vec<&str> = sorted.iter().map(|s| s.as_str()).collect();
        assert_eq!(got, vec!["a", "k", "m", "zz"]);
        assert_eq!(rank, vec![2, 0, 3, 1]);
        for (id, &r) in rank.iter().enumerate() {
            assert_eq!(sorted[r as usize].as_str(), ["m", "a", "zz", "k"][id]);
        }
    }

    #[test]
    fn into_sorted_skips_sort_when_presorted() {
        let mut d = StrDict::new();
        for s in ["a", "b", "c"] {
            d.intern_str(s);
        }
        let (sorted, rank) = d.into_sorted();
        assert_eq!(rank, vec![0, 1, 2]);
        let got: Vec<&str> = sorted.iter().map(|s| s.as_str()).collect();
        assert_eq!(got, vec!["a", "b", "c"]);
    }

    #[test]
    fn into_sorted_resolves_long_prefix_ties() {
        let mut d = StrDict::new();
        for s in ["aaaaaaaaZZ", "aaaaaaaaAA", "aaaaaaaa"] {
            d.intern_str(s);
        }
        let (sorted, rank) = d.into_sorted();
        let got: Vec<&str> = sorted.iter().map(|s| s.as_str()).collect();
        assert_eq!(got, vec!["aaaaaaaa", "aaaaaaaaAA", "aaaaaaaaZZ"]);
        assert_eq!(rank, vec![2, 1, 0]);
    }

    #[test]
    fn into_sorted_keeps_trailing_nul_keys_distinct() {
        // "abc" vs "abc\0": equal zero-padded digests must fall back to
        // the full compare, not id order.
        let mut d = StrDict::new();
        for s in ["abc\0", "abc"] {
            d.intern_str(s);
        }
        let (sorted, rank) = d.into_sorted();
        let got: Vec<&str> = sorted.iter().map(|s| s.as_str()).collect();
        assert_eq!(got, vec!["abc", "abc\0"]);
        assert_eq!(rank, vec![1, 0]);
    }

    #[test]
    fn empty_dict() {
        let d = StrDict::new();
        assert!(d.is_empty());
        let (sorted, rank) = d.into_sorted();
        assert!(sorted.is_empty());
        assert!(rank.is_empty());
    }
}
