//! SplitMix64 pseudo-random number generator.
//!
//! Deterministic, seedable, fast, and good enough for workload generation
//! and property testing (it passes BigCrush when used as a 64-bit
//! generator). Used everywhere the paper's benchmark setup (§III.A) calls
//! for "uniformly random" keys and values so runs are reproducible.

/// SplitMix64 PRNG (Steele, Lea & Flood, OOPSLA 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform `u64` in `[0, bound)`. `bound` must be nonzero.
    ///
    /// Uses Lemire's multiply-shift rejection method to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random boolean with probability `p` of being `true`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random lowercase ASCII string of length `len`.
    pub fn ascii_lower(&mut self, len: usize) -> String {
        (0..len)
            .map(|_| (b'a' + self.below(26) as u8) as char)
            .collect()
    }

    /// Choose a uniformly random element of a nonempty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent generator (for per-thread streams).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = SplitMix64::new(99);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            // Expect 10_000 each; allow ±5%.
            assert!((9_500..=10_500).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn range_i64_inclusive() {
        let mut r = SplitMix64::new(3);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(11);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn ascii_lower_shape() {
        let mut r = SplitMix64::new(5);
        let s = r.ascii_lower(8);
        assert_eq!(s.len(), 8);
        assert!(s.bytes().all(|b| b.is_ascii_lowercase()));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = SplitMix64::new(1234);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
