//! Timing helpers for the benchmark harness.
//!
//! The paper (§III.A) reports running time in seconds averaged over 10
//! runs; [`time_op`] mirrors that protocol (configurable warmup + repeat
//! count) and additionally records min/median so outliers are visible.

use std::time::{Duration, Instant};

/// Statistics from a repeated timing run.
#[derive(Debug, Clone)]
pub struct Timings {
    /// Per-repeat durations, in order of execution.
    pub samples: Vec<Duration>,
}

impl Timings {
    /// Arithmetic mean of the samples, in seconds.
    pub fn mean_s(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(Duration::as_secs_f64).sum::<f64>() / self.samples.len() as f64
    }

    /// Minimum sample, in seconds.
    pub fn min_s(&self) -> f64 {
        self.samples
            .iter()
            .map(Duration::as_secs_f64)
            .fold(f64::INFINITY, f64::min)
    }

    /// Median sample, in seconds.
    pub fn median_s(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.samples.iter().map(Duration::as_secs_f64).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        }
    }

    /// Sample standard deviation, in seconds (0 for < 2 samples).
    pub fn stddev_s(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean_s();
        let var = self
            .samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - m;
                x * x
            })
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }
}

/// Time `op` with `warmup` untimed runs followed by `repeats` timed runs.
///
/// `op` receives the repeat index; its return value is passed to a sink so
/// the optimizer cannot elide the work.
pub fn time_op<T>(warmup: usize, repeats: usize, mut op: impl FnMut(usize) -> T) -> Timings {
    for i in 0..warmup {
        black_box(op(i));
    }
    let mut samples = Vec::with_capacity(repeats);
    for i in 0..repeats {
        let t0 = Instant::now();
        black_box(op(i));
        samples.push(t0.elapsed());
    }
    Timings { samples }
}

/// Opaque value sink preventing dead-code elimination of benchmark bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A simple running stopwatch for phase timing inside examples.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start a new stopwatch.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Reset and return elapsed seconds (lap time).
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_op_runs_expected_counts() {
        let mut calls = 0usize;
        let t = time_op(2, 5, |_| {
            calls += 1;
            calls
        });
        assert_eq!(calls, 7);
        assert_eq!(t.samples.len(), 5);
    }

    #[test]
    fn stats_on_known_samples() {
        let t = Timings {
            samples: vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(30),
            ],
        };
        assert!((t.mean_s() - 0.020).abs() < 1e-9);
        assert!((t.median_s() - 0.020).abs() < 1e-9);
        assert!((t.min_s() - 0.010).abs() < 1e-9);
        assert!(t.stddev_s() > 0.0);
    }

    #[test]
    fn empty_timings_are_zero() {
        let t = Timings { samples: vec![] };
        assert_eq!(t.mean_s(), 0.0);
        assert_eq!(t.median_s(), 0.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        let lap = sw.lap_s();
        assert!(lap >= 0.004);
        assert!(sw.elapsed_s() < lap);
    }
}
