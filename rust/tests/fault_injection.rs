//! Fault-injection harness for the durable storage tier (PR 7).
//!
//! Every test drives a durable [`Table`] through a [`FaultyIo`] backend
//! whose [`FaultPlan`] schedules faults by global operation index. The
//! core property, checked exhaustively in [`seeded_fault_sweep`], is:
//! for **every** operation index and **every** fault kind, each durable
//! operation either succeeds (possibly after retries) or fails with a
//! typed error — and a clean [`Table::recover`] afterwards always
//! restores a *prefix-consistent* table: exactly the state produced by
//! replaying some prefix of the acknowledged operations. Silent
//! corruption (`FaultKind::Corrupt`) may shorten the prefix; every
//! other kind must preserve all acknowledged operations.
//!
//! The remaining tests pin down the individual robustness features:
//! scan-time corruption quarantine (bit-identical to dropping the bad
//! run), the degradation ladder (`Healthy → DegradedReadOnly` /
//! `InMemoryOnly`), compaction failure isolation, orphan run GC,
//! retry-healed transient faults, crashes *during* recovery, and
//! [`BatchWriter`] buffer retention under storage errors.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use d4m::store::{
    BatchWriter, CompactionSpec, DurableOptions, FaultKind, FaultPlan, FaultyIo, FsyncPolicy,
    RealIo, Run, ScanRange, SharedStr, StoreError, Table, TableConfig, TableHealth, Triple,
    WriterConfig,
};
use d4m::util::{RetryPolicy, SplitMix64};

/// One step of the fault-sweep workload.
#[derive(Debug, Clone)]
enum FOp {
    Put(Vec<Triple>),
    Del(String, String),
    Minor,
    Major,
    Sync,
}

/// Deterministic workload: mixed puts/deletes over a small keyspace
/// with compactions and a final sync spliced in, so the sweep schedules
/// faults into WAL appends, fsyncs, run saves, manifest rewrites, and
/// orphan GC alike.
fn fault_workload(seed: u64) -> Vec<FOp> {
    let mut rng = SplitMix64::new(seed);
    let mut ops = Vec::new();
    for i in 0..16usize {
        if rng.chance(0.25) {
            ops.push(FOp::Del(
                format!("r{:02}", rng.below(12)),
                format!("c{}", rng.below(3)),
            ));
        } else {
            let k = 1 + rng.below_usize(3);
            let batch = (0..k)
                .map(|_| {
                    Triple::new(
                        format!("r{:02}", rng.below(12)),
                        format!("c{}", rng.below(3)),
                        format!("v{}", rng.below(100)),
                    )
                })
                .collect();
            ops.push(FOp::Put(batch));
        }
        if i == 5 || i == 13 {
            ops.push(FOp::Minor);
        }
        if i == 9 {
            ops.push(FOp::Major);
        }
    }
    ops.push(FOp::Sync);
    ops
}

/// Small split threshold so workloads exercise multi-tablet tables
/// (and therefore multi-run checkpoints).
fn cfg() -> TableConfig {
    TableConfig { split_threshold: 256, write_latency_us: 0 }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("d4m-fault-tests")
        .join(format!("{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Apply one workload step; mutation acks are recorded by the caller,
/// compaction/sync errors are the fault under test and are ignored.
fn apply_faulty(t: &Table, op: &FOp) -> Result<(), StoreError> {
    match op {
        FOp::Put(batch) => t.write_batch(batch.clone()).map(|_| ()),
        FOp::Del(r, c) => t.delete(r, c).map(|_| ()),
        FOp::Minor => {
            let _ = t.minor_compact();
            Ok(())
        }
        FOp::Major => {
            let _ = t.major_compact(&CompactionSpec::default());
            Ok(())
        }
        FOp::Sync => {
            let _ = t.sync();
            Ok(())
        }
    }
}

/// Scans of every acked-prefix replay: `result[k]` is the full scan of
/// an in-memory table after applying `acked[..k]`.
fn prefix_scans(acked: &[FOp]) -> Vec<Vec<Triple>> {
    let model = Table::new("model", cfg());
    let mut scans = vec![model.scan(ScanRange::all())];
    for op in acked {
        match op {
            FOp::Put(batch) => {
                model.write_batch(batch.clone()).unwrap();
            }
            FOp::Del(r, c) => {
                model.delete(r, c).unwrap();
            }
            _ => unreachable!("only mutations are acked"),
        }
        scans.push(model.scan(ScanRange::all()));
    }
    scans
}

fn opts(io: &Arc<FaultyIo>, retry: RetryPolicy, fallback: bool) -> DurableOptions {
    DurableOptions { io: io.clone(), retry, fallback_to_memory: fallback, ..Default::default() }
}

fn sweep_seeds() -> Vec<u64> {
    let n: u64 = std::env::var("D4M_FAULT_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    (0..n.max(1)).map(|i| 0xFA17_0000 + i).collect()
}

const ALL_KINDS: [FaultKind; 6] = [
    FaultKind::Transient,
    FaultKind::Permanent,
    FaultKind::ShortWrite,
    FaultKind::FsyncFail,
    FaultKind::Enospc,
    FaultKind::Corrupt,
];

/// The tentpole property: schedule every fault kind at every single
/// operation index of a seeded workload; whatever happens in-session,
/// a clean recovery must land on a prefix of the acknowledged
/// operations (the full set unless the fault was silent corruption),
/// and recovering twice must be idempotent.
#[test]
fn seeded_fault_sweep() {
    for seed in sweep_seeds() {
        let ops = fault_workload(seed);

        // Probe run with a fault-free injector to learn the schedule
        // length: how many storage operations the workload performs.
        let total = {
            let dir = temp_dir(&format!("sweep-probe-{seed:x}"));
            let io = FaultyIo::new(FaultPlan::new());
            let t = Table::durable_with(
                "t",
                cfg(),
                &dir,
                FsyncPolicy::Never,
                opts(&io, RetryPolicy::immediate(3), false),
            )
            .unwrap();
            for op in &ops {
                apply_faulty(&t, op).unwrap();
            }
            drop(t);
            let _ = std::fs::remove_dir_all(&dir);
            io.ops()
        };
        assert!(total > 0);

        for kind in ALL_KINDS {
            for idx in 0..total {
                let dir = temp_dir(&format!("sweep-{seed:x}-{kind:?}-{idx}"));
                let io = FaultyIo::new(FaultPlan::new().fail_at(idx, kind));
                let mut acked: Vec<FOp> = Vec::new();
                // On Err the fault killed table creation itself and
                // nothing was acknowledged.
                if let Ok(t) = Table::durable_with(
                    "t",
                    cfg(),
                    &dir,
                    FsyncPolicy::Never,
                    opts(&io, RetryPolicy::immediate(3), false),
                ) {
                    for op in &ops {
                        let ok = apply_faulty(&t, op).is_ok();
                        if ok && matches!(op, FOp::Put(_) | FOp::Del(..)) {
                            acked.push(op.clone());
                        }
                    }
                }

                let recovered =
                    Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap_or_else(|e| {
                        panic!("clean recovery failed (seed {seed:x} {kind:?}@{idx}): {e}")
                    });
                let scan = recovered.scan(ScanRange::all());
                let prefixes = prefix_scans(&acked);
                if kind == FaultKind::Corrupt {
                    assert!(
                        prefixes.contains(&scan),
                        "not prefix-consistent (seed {seed:x} Corrupt@{idx}): \
                         {} cells vs {} acked ops",
                        scan.len(),
                        acked.len()
                    );
                } else {
                    assert_eq!(
                        scan,
                        *prefixes.last().unwrap(),
                        "acked op lost (seed {seed:x} {kind:?}@{idx})"
                    );
                }
                drop(recovered);

                // Recovery is idempotent: a second pass over the same
                // directory lands on the identical image.
                let again = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
                assert_eq!(again.scan(ScanRange::all()), scan);
                drop(again);
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

/// Write two-plus runs, corrupt one on disk, and recover: the damaged
/// run is quarantined (renamed aside, reported, dropped from the
/// manifest) and the degraded scan is bit-identical to recovering the
/// same directory with that run's manifest line removed.
#[test]
fn corrupt_run_scan_quarantines_bit_identical() {
    let dir1 = temp_dir("quarantine-a");
    {
        let t = Table::durable("t", cfg(), &dir1, FsyncPolicy::Never).unwrap();
        let batch: Vec<Triple> = (0..30)
            .map(|i| Triple::new(format!("r{i:02}"), "c0", format!("v{i}")))
            .collect();
        t.write_batch(batch).unwrap();
        t.minor_compact().unwrap();
    }
    // One clean recovery settles the image: the replayed suffix is
    // frozen and the fresh WAL is empty, so the runs alone carry the
    // data (the interesting quarantine case — the log can no longer
    // backfill).
    let full = {
        let t = Table::recover("t", cfg(), &dir1, FsyncPolicy::Never).unwrap();
        t.scan(ScanRange::all())
    };

    // Manifest lines are split points (PR 8) followed by run names;
    // only the run names are corruption candidates here.
    let manifest = std::fs::read_to_string(dir1.join("MANIFEST")).unwrap();
    let runs: Vec<&str> = manifest
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with("split:"))
        .collect();
    assert!(runs.len() >= 2, "need multiple runs, got {runs:?}");
    let victim = runs.last().unwrap().to_string();

    // dir2 = same directory with the victim dropped explicitly.
    let dir2 = temp_dir("quarantine-b");
    copy_dir(&dir1, &dir2);
    let kept: String = runs[..runs.len() - 1]
        .iter()
        .map(|r| format!("{r}\n"))
        .collect();
    std::fs::write(dir2.join("MANIFEST"), kept).unwrap();
    std::fs::remove_file(dir2.join(&victim)).unwrap();

    // dir1: flip one byte in the middle of the victim run file.
    let victim_path = dir1.join(&victim);
    let mut bytes = std::fs::read(&victim_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim_path, bytes).unwrap();

    let t1 = Table::recover("t", cfg(), &dir1, FsyncPolicy::Never).unwrap();
    let h = t1.health();
    assert_eq!(h.quarantined, vec![victim.clone()]);
    assert_eq!(h.state, TableHealth::Healthy);
    assert!(h.last_error.is_some());
    assert!(dir1.join(format!("{victim}.quarantined")).exists());
    let rewritten = std::fs::read_to_string(dir1.join("MANIFEST")).unwrap();
    assert!(!rewritten.contains(&victim), "quarantined run still listed");

    let t2 = Table::recover("t", cfg(), &dir2, FsyncPolicy::Never).unwrap();
    assert!(t2.health().quarantined.is_empty());

    let degraded = t1.scan(ScanRange::all());
    assert_eq!(degraded, t2.scan(ScanRange::all()), "quarantine must equal dropping the run");
    assert_ne!(degraded, full, "victim run held unique cells");

    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir2);
}

/// A persistently failing WAL flips the table to `DegradedReadOnly`:
/// the failing write surfaces a typed permanent error, later writes
/// are rejected with `StoreError::Degraded`, reads keep serving, and
/// `sync` reports the condition.
#[test]
fn persistent_wal_failure_degrades_read_only() {
    let dir = temp_dir("degrade-ro");
    let io = FaultyIo::new(FaultPlan::new());
    let t = Table::durable_with(
        "t",
        cfg(),
        &dir,
        FsyncPolicy::Never,
        opts(&io, RetryPolicy::immediate(2), false),
    )
    .unwrap();
    t.write_batch(vec![Triple::new("a", "b", "1")]).unwrap();

    io.fail_from_now(FaultKind::Permanent);
    let err = t.write_batch(vec![Triple::new("c", "d", "2")]).unwrap_err();
    match &err {
        StoreError::Io { transient, .. } => assert!(!*transient),
        other => panic!("expected permanent Io error, got {other:?}"),
    }
    assert!(!err.is_transient());
    assert_eq!(t.health().state, TableHealth::DegradedReadOnly);

    // Next write is rejected up front with the ladder error.
    match t.write_batch(vec![Triple::new("e", "f", "3")]) {
        Err(StoreError::Degraded { state, .. }) => {
            assert_eq!(state, TableHealth::DegradedReadOnly)
        }
        other => panic!("expected Degraded, got {other:?}"),
    }
    assert!(matches!(t.delete("a", "b"), Err(StoreError::Degraded { .. })));

    // Reads still serve the pre-failure state; sync reports the fault.
    assert_eq!(t.get("a", "b").as_deref(), Some("1"));
    assert_eq!(t.len(), 1);
    assert!(t.sync().is_err());
    assert!(t.health().last_error.is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

/// `DegradedReadOnly` is not a terminal state (PR 8): the next durable
/// write re-probes the WAL by reopening a fresh handle. While the
/// device stays dead the probe fails and the write is still rejected
/// with `Degraded`; once it heals, the write goes through, health flips
/// back to `Healthy` (counting the reopen), and recovery sees every
/// acknowledged mutation — including those from after the heal.
#[test]
fn degraded_read_only_auto_recovers() {
    let dir = temp_dir("degrade-recover");
    let io = FaultyIo::new(FaultPlan::new());
    let t = Table::durable_with(
        "t",
        cfg(),
        &dir,
        FsyncPolicy::Never,
        opts(&io, RetryPolicy::immediate(2), false),
    )
    .unwrap();
    t.write_batch(vec![Triple::new("a", "b", "1")]).unwrap();

    io.fail_from_now(FaultKind::Permanent);
    assert!(t.write_batch(vec![Triple::new("c", "d", "2")]).is_err());
    assert_eq!(t.health().state, TableHealth::DegradedReadOnly);

    // Device still dead: the re-probe fails and the ladder error stands.
    match t.write_batch(vec![Triple::new("c", "d", "2")]) {
        Err(StoreError::Degraded { state, .. }) => {
            assert_eq!(state, TableHealth::DegradedReadOnly)
        }
        other => panic!("expected Degraded while device is down, got {other:?}"),
    }
    assert_eq!(t.health().wal_reopens, 0);

    // Device heals: the next write's re-probe reopens the WAL and the
    // write itself succeeds durably.
    io.clear();
    t.write_batch(vec![Triple::new("c", "d", "2")]).unwrap();
    let h = t.health();
    assert_eq!(h.state, TableHealth::Healthy);
    assert!(h.wal_reopens >= 1, "reopen not counted: {h:?}");
    assert!(h.last_error.is_none(), "healed table still reports {:?}", h.last_error);

    // Deletes ride the same path; keep writing after the heal.
    assert!(t.delete("a", "b").unwrap());
    t.write_batch(vec![Triple::new("e", "f", "3")]).unwrap();
    assert_eq!(t.len(), 2);
    drop(t);

    let r = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
    assert_eq!(
        r.scan(ScanRange::all()),
        vec![Triple::new("c", "d", "2"), Triple::new("e", "f", "3")],
        "acked post-heal writes must survive recovery"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// With `fallback_to_memory`, a dead WAL drops the table to
/// `InMemoryOnly` instead: writes keep succeeding (non-durably,
/// counted), reads see them, and `sync` reports the condition. A later
/// recovery only finds the durable prefix.
#[test]
fn persistent_wal_failure_falls_back_to_memory() {
    let dir = temp_dir("degrade-mem");
    let io = FaultyIo::new(FaultPlan::new());
    let t = Table::durable_with(
        "t",
        cfg(),
        &dir,
        FsyncPolicy::Never,
        opts(&io, RetryPolicy::immediate(2), true),
    )
    .unwrap();
    t.write_batch(vec![Triple::new("a", "b", "1")]).unwrap();

    io.fail_from_now(FaultKind::Permanent);
    t.write_batch(vec![Triple::new("c", "d", "2")]).unwrap();
    assert_eq!(t.health().state, TableHealth::InMemoryOnly);
    t.write_batch(vec![Triple::new("e", "f", "3")]).unwrap();
    assert!(t.delete("a", "b").unwrap());
    let h = t.health();
    assert!(h.non_durable_writes >= 3, "got {}", h.non_durable_writes);

    // Reads serve the in-memory state...
    assert_eq!(t.get("c", "d").as_deref(), Some("2"));
    assert_eq!(t.get("a", "b"), None);
    // ...but durability is gone and sync says so.
    assert!(t.sync().is_err());
    drop(t);

    let r = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
    let scan = r.scan(ScanRange::all());
    assert_eq!(scan, vec![Triple::new("a", "b", "1")], "only the durable prefix survives");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A failed compaction leaves the table and its manifest untouched and
/// is safely re-runnable once storage heals — for both minor and major
/// compactions.
#[test]
fn compaction_failure_leaves_state_and_is_rerunnable() {
    let dir = temp_dir("compact-isolate");
    let io = FaultyIo::new(FaultPlan::new());
    // Single tablet + no retry so operation indices are predictable:
    // a compaction is [wal sync][run save][manifest write][gc read +
    // read_dir].
    let t = Table::durable_with(
        "t",
        TableConfig::default(),
        &dir,
        FsyncPolicy::Never,
        opts(&io, RetryPolicy::none(), false),
    )
    .unwrap();
    for i in 0..8 {
        t.write_batch(vec![Triple::new(format!("r{i}"), "c", format!("v{i}"))]).unwrap();
    }
    let before = t.scan(ScanRange::all());

    // Fail the run save (the op after the WAL sync).
    io.schedule(io.ops() + 1, FaultKind::Permanent);
    assert!(t.minor_compact().is_err());
    assert_eq!(t.scan(ScanRange::all()), before, "failed minor changed visible state");
    assert_eq!(t.run_count(), 0);
    assert_eq!(t.health().state, TableHealth::Healthy, "checkpoint failure must not degrade");
    assert!(!dir.join("MANIFEST").exists(), "failed minor wrote a manifest");

    io.clear();
    assert!(t.minor_compact().unwrap() > 0, "re-run after healing");
    assert_eq!(t.scan(ScanRange::all()), before);
    assert_eq!(t.run_count(), 1);

    // Same isolation for a major compaction over existing runs.
    t.write_batch(vec![Triple::new("r0", "c", "patched")]).unwrap();
    let before = t.scan(ScanRange::all());
    let manifest = std::fs::read_to_string(dir.join("MANIFEST")).unwrap();
    io.schedule(io.ops() + 1, FaultKind::Permanent);
    assert!(t.major_compact(&CompactionSpec::default()).is_err());
    assert_eq!(t.scan(ScanRange::all()), before, "failed major changed visible state");
    assert_eq!(t.run_count(), 1);
    assert_eq!(std::fs::read_to_string(dir.join("MANIFEST")).unwrap(), manifest);

    io.clear();
    t.major_compact(&CompactionSpec::default()).unwrap();
    assert_eq!(t.scan(ScanRange::all()), before);
    drop(t);

    let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
    assert_eq!(r.scan(ScanRange::all()), before);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Successful compactions and recoveries garbage-collect run files no
/// longer referenced by the manifest — and only those.
#[test]
fn orphan_runs_collected_after_compaction() {
    let dir = temp_dir("orphan-gc");
    let io = FaultyIo::new(FaultPlan::new());
    let t = Table::durable_with(
        "t",
        TableConfig::default(),
        &dir,
        FsyncPolicy::Never,
        opts(&io, RetryPolicy::immediate(1), false),
    )
    .unwrap();
    t.write_batch(vec![Triple::new("a", "c", "1")]).unwrap();
    t.minor_compact().unwrap();
    t.write_batch(vec![Triple::new("b", "c", "2")]).unwrap();
    t.minor_compact().unwrap();
    assert!(dir.join("run-00000001.run").exists());
    assert!(dir.join("run-00000002.run").exists());

    t.major_compact(&CompactionSpec::default()).unwrap();
    assert!(!dir.join("run-00000001.run").exists(), "superseded run not GC'd");
    assert!(!dir.join("run-00000002.run").exists(), "superseded run not GC'd");
    assert!(dir.join("run-00000003.run").exists());
    assert!(t.health().orphans_removed >= 2);
    let expected = t.scan(ScanRange::all());
    drop(t);

    // Recovery GC: a stray run file (crash between save and manifest
    // commit) is collected; unrelated files are untouched.
    std::fs::write(dir.join("run-99999999.run"), b"junk").unwrap();
    std::fs::write(dir.join("foo.txt"), b"keep me").unwrap();
    let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
    assert!(!dir.join("run-99999999.run").exists(), "stray run survived recovery GC");
    assert!(dir.join("foo.txt").exists(), "GC deleted an unrelated file");
    assert!(r.health().orphans_removed >= 1);
    assert_eq!(r.scan(ScanRange::all()), expected);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Periodic transient faults are fully healed by the retry layer: the
/// whole workload succeeds, and recovery matches the fault-free model.
#[test]
fn transient_faults_healed_by_retry() {
    let seed = 0x7EA1u64;
    let dir = temp_dir("transient-heal");
    let io = FaultyIo::new(FaultPlan::new().fail_every(7, FaultKind::Transient));
    let t = Table::durable_with(
        "t",
        cfg(),
        &dir,
        FsyncPolicy::Never,
        opts(&io, RetryPolicy::immediate(3), false),
    )
    .unwrap();
    let ops = fault_workload(seed);
    let mut acked = Vec::new();
    for op in &ops {
        match op {
            FOp::Put(batch) => {
                t.write_batch(batch.clone()).expect("retry must heal transient fault");
                acked.push(op.clone());
            }
            FOp::Del(r, c) => {
                t.delete(r, c).expect("retry must heal transient fault");
                acked.push(op.clone());
            }
            FOp::Minor => {
                t.minor_compact().expect("retry must heal transient fault");
            }
            FOp::Major => {
                t.major_compact(&CompactionSpec::default())
                    .expect("retry must heal transient fault");
            }
            FOp::Sync => t.sync().expect("retry must heal transient fault"),
        }
    }
    assert_eq!(t.health().state, TableHealth::Healthy);
    assert!(io.injected() > 0, "the plan never fired");
    drop(t);

    let r = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
    assert_eq!(r.scan(ScanRange::all()), *prefix_scans(&acked).last().unwrap());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash *during* recovery, at every operation index: a third, clean
/// recovery must still restore the full pre-crash state (recovery
/// checkpoints and rewrites the manifest before truncating the old
/// log), and a fourth pass is idempotent.
#[test]
fn double_crash_during_recovery() {
    let base = temp_dir("double-crash-base");
    {
        let t = Table::durable("t", cfg(), &base, FsyncPolicy::Never).unwrap();
        // The workload compacts partway through and keeps writing, so
        // recovery has runs to load *and* a log suffix to re-freeze.
        for op in &fault_workload(0x8CADE) {
            match op {
                FOp::Put(batch) => {
                    t.write_batch(batch.clone()).unwrap();
                }
                FOp::Del(r, c) => {
                    t.delete(r, c).unwrap();
                }
                FOp::Minor => {
                    t.minor_compact().unwrap();
                }
                FOp::Major => {
                    t.major_compact(&CompactionSpec::default()).unwrap();
                }
                FOp::Sync => t.sync().unwrap(),
            }
        }
    }
    let expected = {
        let probe = temp_dir("double-crash-probe");
        copy_dir(&base, &probe);
        let t = Table::recover("t", cfg(), &probe, FsyncPolicy::Never).unwrap();
        let scan = t.scan(ScanRange::all());
        drop(t);
        let _ = std::fs::remove_dir_all(&probe);
        scan
    };

    // Count the storage operations one full recovery performs.
    let total = {
        let probe = temp_dir("double-crash-count");
        copy_dir(&base, &probe);
        let io = FaultyIo::new(FaultPlan::new());
        let t = Table::recover_with(
            "t",
            cfg(),
            &probe,
            FsyncPolicy::Never,
            opts(&io, RetryPolicy::none(), false),
        )
        .unwrap();
        drop(t);
        let _ = std::fs::remove_dir_all(&probe);
        io.ops()
    };
    assert!(total > 0);

    for idx in 0..total {
        let dir = temp_dir(&format!("double-crash-{idx}"));
        copy_dir(&base, &dir);
        let io = FaultyIo::new(FaultPlan::new().fail_at(idx, FaultKind::Permanent));
        let first = Table::recover_with(
            "t",
            cfg(),
            &dir,
            FsyncPolicy::Never,
            opts(&io, RetryPolicy::none(), false),
        );
        if let Ok(t) = &first {
            // Fault landed on a best-effort path (orphan GC); the
            // recovered table must already be complete.
            assert_eq!(t.scan(ScanRange::all()), expected, "crash@{idx}");
        }
        drop(first);

        let third = Table::recover("t", cfg(), &dir, FsyncPolicy::Never)
            .unwrap_or_else(|e| panic!("third recovery failed (crash@{idx}): {e}"));
        assert_eq!(third.scan(ScanRange::all()), expected, "state lost (crash@{idx})");
        drop(third);
        let fourth = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
        assert_eq!(fourth.scan(ScanRange::all()), expected, "not idempotent (crash@{idx})");
        drop(fourth);
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&base);
}

/// `BatchWriter` keeps its buffer across storage-level transient
/// failures and delivers the same mutations once the device heals —
/// end to end through the durable tier (the offline-tablet variant
/// lives in the writer's unit tests).
#[test]
fn batch_writer_retains_buffer_across_storage_faults() {
    let dir = temp_dir("writer-retain");
    let io = FaultyIo::new(FaultPlan::new());
    let t = Arc::new(
        Table::durable_with(
            "t",
            TableConfig::default(),
            &dir,
            FsyncPolicy::Never,
            // No table-level retry: the transient error must reach the
            // writer, whose own retry loop is under test.
            opts(&io, RetryPolicy::none(), false),
        )
        .unwrap(),
    );
    let mut w = BatchWriter::new(
        Arc::clone(&t),
        WriterConfig {
            max_retries: 1,
            retry_backoff: std::time::Duration::ZERO,
            ..WriterConfig::default()
        },
    );
    for i in 0..3 {
        w.put(Triple::new(format!("r{i}"), "c", format!("v{i}")));
    }

    io.fail_from_now(FaultKind::Transient);
    let err = w.flush().unwrap_err();
    assert!(err.is_transient(), "got {err:?}");
    assert_eq!(w.buffered(), 3, "failed flush dropped the buffer");
    assert_eq!(t.len(), 0, "partial apply after failed WAL append");

    io.clear();
    assert_eq!(w.flush().unwrap(), 3);
    assert_eq!(w.buffered(), 0);
    assert_eq!(t.len(), 3);
    w.sync().unwrap();
    drop(w);
    drop(t);

    let r = Table::recover("t", TableConfig::default(), &dir, FsyncPolicy::Never).unwrap();
    assert_eq!(r.len(), 3);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Copy the top-level files of `src` into `dst` (table directories are
/// flat).
fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        if entry.file_type().unwrap().is_file() {
            std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
        }
    }
}

// ---------------------------------------------------------------------
// Block-granular run I/O section (PR 9)
// ---------------------------------------------------------------------

/// Single-tablet config for the block tests: the run layout must stay
/// exactly "one run per minor compaction" so victims are predictable.
fn block_cfg() -> TableConfig {
    TableConfig { split_threshold: 100_000, write_latency_us: 0 }
}

/// Build a settled table whose run files use tiny (32-triple) data
/// blocks: a 200-cell `a*` run with content no other run covers, a
/// 40-cell `z*` run, plus the replay-frozen duplicate of the `z`
/// suffix. Returns the directory and the settled full scan.
fn build_block_dir(tag: &str) -> (PathBuf, Vec<Triple>) {
    let dir = temp_dir(tag);
    {
        let o = DurableOptions { block_triples: 32, ..Default::default() };
        let t = Table::durable_with("t", block_cfg(), &dir, FsyncPolicy::Never, o).unwrap();
        let batch: Vec<Triple> = (0..200)
            .map(|i| Triple::new(format!("a{i:03}"), "c0", format!("v{i}")))
            .collect();
        t.write_batch(batch).unwrap();
        t.minor_compact().unwrap();
        let batch: Vec<Triple> = (0..40)
            .map(|i| Triple::new(format!("z{i:02}"), "c0", format!("w{i}")))
            .collect();
        t.write_batch(batch).unwrap();
        t.minor_compact().unwrap();
    }
    // Settle: the WAL suffix is frozen to a run and truncated, so from
    // here the run files alone carry the data.
    let full = {
        let t = Table::recover("t", block_cfg(), &dir, FsyncPolicy::Never).unwrap();
        t.scan(ScanRange::all())
    };
    assert_eq!(full.len(), 240);
    (dir, full)
}

/// Run-format versioning: hand-written v1 (pre-block) run files recover
/// byte-identically in both resident and paged mode — the paged open
/// probes the magic and falls back to a fully resident load for v1.
#[test]
fn v1_run_files_recover_across_versions() {
    let dir = temp_dir("v1-compat");
    let cell = |r: &str, c: &str, v: Option<&str>| {
        (SharedStr::from(r), SharedStr::from(c), v.map(SharedStr::from))
    };
    // Run 1 (older): three hand keys plus filler, all live.
    let mut cells1 = vec![
        cell("a0", "c0", Some("old")),
        cell("a1", "c0", Some("keep1")),
        cell("a2", "c0", Some("dead")),
    ];
    for i in 0..100 {
        cells1.push(cell(&format!("f{i:03}"), "c0", Some(&format!("v{i}"))));
    }
    // Run 2 (newer): shadows a0, tombstones a2, adds b0.
    let cells2 = vec![
        cell("a0", "c0", Some("new")),
        cell("a2", "c0", None),
        cell("b0", "c0", Some("b")),
    ];
    let io = RealIo;
    Run::from_cells(1, 0, &cells1)
        .save_v1_with(&io, &dir.join("run-00000001.run"))
        .unwrap();
    Run::from_cells(2, 0, &cells2)
        .save_v1_with(&io, &dir.join("run-00000002.run"))
        .unwrap();
    std::fs::write(dir.join("MANIFEST"), "run-00000001.run\nrun-00000002.run\n").unwrap();

    let mut expect = vec![
        Triple::new("a0", "c0", "new"),
        Triple::new("a1", "c0", "keep1"),
        Triple::new("b0", "c0", "b"),
    ];
    for i in 0..100 {
        expect.push(Triple::new(format!("f{i:03}"), "c0", format!("v{i}")));
    }

    let resident = Table::recover("t", block_cfg(), &dir, FsyncPolicy::Never).unwrap();
    assert!(resident.health().quarantined.is_empty());
    assert_eq!(resident.scan(ScanRange::all()), expect, "resident v1 recovery");
    drop(resident);

    let o = DurableOptions::default().cache_capacity(usize::MAX);
    let paged = Table::recover_with("t", block_cfg(), &dir, FsyncPolicy::Never, o).unwrap();
    assert!(paged.health().quarantined.is_empty());
    assert_eq!(paged.scan(ScanRange::all()), expect, "paged v1 recovery (resident fallback)");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A flipped byte inside one *data block* of a paged run: recovery
/// (footer-only) still succeeds, the first scan to fault the block
/// poisons the run without panicking, every later scan is bit-identical
/// to dropping the whole run, and `sync` makes the quarantine durable
/// exactly like the whole-run corruption path (rename aside + manifest
/// rewrite + health report).
#[test]
fn block_corruption_quarantines_like_whole_run() {
    let (dir1, full) = build_block_dir("block-quarantine-a");

    let manifest = std::fs::read_to_string(dir1.join("MANIFEST")).unwrap();
    let runs: Vec<String> = manifest
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with("split:"))
        .map(str::to_string)
        .collect();
    assert!(runs.len() >= 2, "need multiple runs, got {runs:?}");
    // The largest run is the 200-cell `a*` one — multi-block at 32
    // triples per block, and the only copy of its cells.
    let victim = runs
        .iter()
        .max_by_key(|r| std::fs::metadata(dir1.join(r.as_str())).unwrap().len())
        .unwrap()
        .clone();

    // dir2 = same image with the victim dropped explicitly.
    let dir2 = temp_dir("block-quarantine-b");
    copy_dir(&dir1, &dir2);
    let kept: String = runs
        .iter()
        .filter(|r| **r != victim)
        .map(|r| format!("{r}\n"))
        .collect();
    std::fs::write(dir2.join("MANIFEST"), kept).unwrap();
    std::fs::remove_file(dir2.join(&victim)).unwrap();
    let t2 = Table::recover("t", block_cfg(), &dir2, FsyncPolicy::Never).unwrap();
    let baseline = t2.scan(ScanRange::all());
    assert_ne!(baseline, full, "victim run held unique cells");

    // Flip one byte inside the victim's first data block (blocks start
    // right after the 8-byte magic; the footer is far away at EOF).
    let victim_path = dir1.join(&victim);
    let mut bytes = std::fs::read(&victim_path).unwrap();
    bytes[8 + 10] ^= 0xFF;
    std::fs::write(&victim_path, bytes).unwrap();

    let o = DurableOptions::default().cache_capacity(usize::MAX);
    let t1 = Table::recover_with("t", block_cfg(), &dir1, FsyncPolicy::Never, o).unwrap();
    assert!(
        t1.health().quarantined.is_empty(),
        "footer-only open must not fault (or validate) data blocks"
    );
    // First scan hits the bad CRC: the run is poisoned mid-scan; the
    // in-flight scan itself only promises to complete without panicking.
    let _mid = t1.scan(ScanRange::all());
    // Every *new* scan skips the poisoned run entirely.
    assert_eq!(t1.scan(ScanRange::all()), baseline, "poisoned run must scan as if dropped");
    // sync() makes it durable: the PR 7 quarantine contract, per block.
    t1.sync().unwrap();
    let h = t1.health();
    assert_eq!(h.quarantined, vec![victim.clone()]);
    assert!(h.last_error.is_some());
    assert!(dir1.join(format!("{victim}.quarantined")).exists());
    let rewritten = std::fs::read_to_string(dir1.join("MANIFEST")).unwrap();
    assert!(!rewritten.contains(&victim), "quarantined run still listed");
    assert_eq!(t1.scan(ScanRange::all()), baseline, "post-quarantine scan");

    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir2);
}

/// An injected I/O failure on a single block *read* (not corruption on
/// disk): with no retry budget the run poisons, later scans equal the
/// table minus that run, and `sync` quarantines it durably.
#[test]
fn block_read_fault_poisons_then_quarantines() {
    let (dir1, full) = build_block_dir("block-fault-a");
    let dir2 = temp_dir("block-fault-b");
    copy_dir(&dir1, &dir2);

    let io = FaultyIo::new(FaultPlan::new());
    let o = opts(&io, RetryPolicy::none(), false).cache_capacity(0);
    let t = Table::recover_with("t", block_cfg(), &dir1, FsyncPolicy::Never, o).unwrap();
    assert_eq!(t.scan(ScanRange::all()), full, "paged scan == resident before faults");

    // Capacity 0 retains nothing, so the next scan must re-read its
    // first block from storage — fail exactly that operation.
    io.schedule(io.ops(), FaultKind::Permanent);
    let _mid = t.scan(ScanRange::all()); // poisons mid-scan; panic-free
    t.sync().unwrap();
    let h = t.health();
    assert_eq!(h.quarantined.len(), 1, "exactly one run poisoned: {:?}", h.quarantined);
    let victim = h.quarantined[0].clone();
    assert!(dir1.join(format!("{victim}.quarantined")).exists());

    // Reference: the pre-fault image with that run dropped explicitly.
    let manifest = std::fs::read_to_string(dir2.join("MANIFEST")).unwrap();
    let kept: String = manifest
        .lines()
        .filter(|l| !l.trim().is_empty() && *l != victim.as_str())
        .map(|l| format!("{l}\n"))
        .collect();
    std::fs::write(dir2.join("MANIFEST"), kept).unwrap();
    std::fs::remove_file(dir2.join(&victim)).unwrap();
    let t2 = Table::recover("t", block_cfg(), &dir2, FsyncPolicy::Never).unwrap();
    let baseline = t2.scan(ScanRange::all());

    assert_eq!(t.scan(ScanRange::all()), baseline, "poisoned run must scan as if dropped");
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir2);
}

/// Transient block-read faults under a retry budget heal invisibly:
/// scans stay byte-identical to the resident image, nothing poisons,
/// nothing is quarantined — even though faults demonstrably fired.
#[test]
fn transient_block_faults_heal_under_retry() {
    let (dir, full) = build_block_dir("block-transient");
    let io = FaultyIo::new(FaultPlan::new().fail_every(7, FaultKind::Transient));
    let o = opts(&io, RetryPolicy::immediate(3), false).cache_capacity(0);
    let t = Table::recover_with("t", block_cfg(), &dir, FsyncPolicy::Never, o).unwrap();
    for round in 0..2 {
        assert_eq!(t.scan(ScanRange::all()), full, "round {round}");
    }
    assert!(io.injected() > 0, "the fault plan never fired");
    let h = t.health();
    assert!(h.quarantined.is_empty(), "transient faults must heal, not quarantine");
    assert_eq!(h.state, TableHealth::Healthy);
    let stats = h.cache.expect("paged mode reports cache stats");
    assert!(stats.misses > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
