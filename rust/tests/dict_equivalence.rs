//! Equivalence suite for the dictionary-encoded key space (PR 4).
//!
//! Contract under test: encoding keys through a dictionary (intern to
//! dense `u32` ids, sort distinct keys, resolve ranks) is **byte-
//! identical** to the PR 1–3 digest-sort path — for the constructor
//! over mixed numeric/string key spaces and every aggregator, for the
//! scan→assoc rebuild across tablet splits and offline tablets, and for
//! the Graphulo `TableMult` ingest — at every thread count. Both paths
//! compute the same canonical form, so any divergence is a bug in one
//! of them.

use d4m::assoc::{Aggregator, Assoc, Key, KeyEncoding, ValsInput};
use d4m::graphulo;
use d4m::semiring::{MaxPlus, PlusTimes, Semiring};
use d4m::sorted::KeyDict;
use d4m::sparse::{spgemm_par, CooMatrix};
use d4m::store::{format_num, ScanRange, ScanSpec, Table, TableConfig, TableStore, Triple};
use d4m::util::prop::check;
use d4m::util::{Parallelism, SplitMix64};

const THREADS: [usize; 3] = [2, 4, 7];

/// Bit-exact associative-array comparison: attributes, structure, and
/// raw value bits (catches `-0.0` drift that `PartialEq` would hide).
fn assert_identical(a: &Assoc, b: &Assoc, ctx: &str) {
    assert_eq!(a.row_keys(), b.row_keys(), "{ctx}: row keys");
    assert_eq!(a.col_keys(), b.col_keys(), "{ctx}: col keys");
    assert_eq!(a.values(), b.values(), "{ctx}: value pool");
    assert_eq!(a.adj().indptr(), b.adj().indptr(), "{ctx}: indptr");
    assert_eq!(a.adj().indices(), b.adj().indices(), "{ctx}: indices");
    let ab: Vec<u64> = a.adj().values().iter().map(|v| v.to_bits()).collect();
    let bb: Vec<u64> = b.adj().values().iter().map(|v| v.to_bits()).collect();
    assert_eq!(ab, bb, "{ctx}: adj value bits");
}

fn random_mixed_keys(rng: &mut SplitMix64, len: usize) -> Vec<Key> {
    (0..len)
        .map(|_| match rng.below(5) {
            0 => Key::str(rng.below(30).to_string()),
            1 => Key::num(rng.range_i64(-30, 30) as f64),
            2 => {
                // Long keys with shared prefixes force digest tie-breaks.
                let mut s = "sharedprefix".to_string();
                s.push_str(&rng.below(20).to_string());
                Key::str(s)
            }
            3 => Key::num(rng.f64() * 8.0 - 4.0),
            _ => Key::str(format!("k{:03}", rng.below(40))),
        })
        .collect()
}

#[test]
fn prop_dict_constructor_matches_sort_encoding() {
    // Every aggregator, numeric and string values, mixed key spaces,
    // serial + parallel: Dict and Sort encodings must agree byte for
    // byte (both compute the canonical sorted-unique key form).
    check("ctor Dict == Sort encoding", 30, |g| {
        let len = 1 + g.rng().below_usize(1600);
        let rows = random_mixed_keys(g.rng(), len);
        let cols = random_mixed_keys(g.rng(), len);
        let numeric = g.rng().chance(0.5);
        let (vals, aggs): (ValsInput, Vec<Aggregator>) = if numeric {
            (
                ValsInput::Num((0..len).map(|_| g.rng().range_i64(-9, 9) as f64).collect()),
                vec![
                    Aggregator::Min,
                    Aggregator::Max,
                    Aggregator::Sum,
                    Aggregator::Prod,
                    Aggregator::First,
                    Aggregator::Last,
                ],
            )
        } else {
            (
                ValsInput::Str((0..len).map(|_| g.rng().ascii_lower(6)).collect()),
                vec![
                    Aggregator::Min,
                    Aggregator::Max,
                    Aggregator::First,
                    Aggregator::Last,
                    Aggregator::Concat(";".into()),
                ],
            )
        };
        for agg in aggs {
            let sort = Assoc::try_new_with(
                rows.clone(),
                cols.clone(),
                vals.clone(),
                agg.clone(),
                Parallelism::serial(),
                KeyEncoding::Sort,
            )
            .unwrap();
            let dict = Assoc::try_new_with(
                rows.clone(),
                cols.clone(),
                vals.clone(),
                agg.clone(),
                Parallelism::serial(),
                KeyEncoding::Dict,
            )
            .unwrap();
            assert_identical(&sort, &dict, &format!("serial {agg:?}"));
            for t in THREADS {
                let par = Parallelism::with_threads(t);
                let dict_par = Assoc::try_new_with(
                    rows.clone(),
                    cols.clone(),
                    vals.clone(),
                    agg.clone(),
                    par,
                    KeyEncoding::Dict,
                )
                .unwrap();
                assert_identical(&sort, &dict_par, &format!("t={t} {agg:?}"));
            }
        }
    });
}

#[test]
fn keydict_order_preservation_against_full_sort() {
    // KeyDict's finalize must rank ids exactly as a full sort of the
    // decoded keys would — including -0.0/0.0 merging and numbers-
    // before-strings ordering.
    let mut rng = SplitMix64::new(0xD1C7);
    for round in 0..50 {
        let keys = random_mixed_keys(&mut rng, 1 + (round * 7) % 300);
        let mut dict = KeyDict::new();
        let ids: Vec<u32> = keys.iter().map(|k| dict.intern(k)).collect();
        // Decode through the dictionary: bit-exact round trip.
        for (k, &id) in keys.iter().zip(&ids) {
            assert_eq!(dict.get(id), k, "round {round}");
        }
        let (sorted, rank) = dict.into_sorted();
        assert!(sorted.windows(2).all(|w| w[0] < w[1]), "round {round}: sorted unique");
        // Every input position resolves to its key's sorted position.
        let expect = d4m::sorted::sort_dedup_keys(&keys);
        assert_eq!(sorted, expect.0, "round {round}: unique keys");
        for (p, &id) in ids.iter().enumerate() {
            assert_eq!(rank[id as usize], expect.1[p], "round {round} pos {p}");
        }
    }
}

/// Random store table with real tablet fan-out: numeric-looking string
/// keys (which must stay *strings* — "10" < "2" lexically — through any
/// encoding), numeric and non-numeric values, overwrites.
fn random_table(rng: &mut SplitMix64, cells: usize, numeric_vals: bool) -> Table {
    let table = Table::new("t", TableConfig { split_threshold: 512, write_latency_us: 0 });
    let triples: Vec<Triple> = (0..cells)
        .map(|_| {
            let val = if numeric_vals {
                format!("{}", rng.range_i64(-50, 100))
            } else {
                format!("v{}", rng.below(40))
            };
            Triple::new(
                format!("{}", rng.below(90)), // numeric-looking string rows
                format!("c{:02}", rng.below(24)),
                val,
            )
        })
        .collect();
    for chunk in triples.chunks(16) {
        table.write_batch(chunk.to_vec()).unwrap();
    }
    table
}

/// The PR 3 scan→assoc path, verbatim: materialize per-cell `Key`s and
/// digest-sort them (`KeyEncoding::Sort`), `Last` aggregation.
/// **Frozen snapshot** — `benches/ablations.rs` carries its twin
/// (`scan_to_assoc_string_path`); change both together or not at all.
fn triples_to_assoc_string_path(triples: &[Triple], par: Parallelism) -> Assoc {
    let rows: Vec<Key> = triples.iter().map(|t| Key::str(t.row.as_str())).collect();
    let cols: Vec<Key> = triples.iter().map(|t| Key::str(t.col.as_str())).collect();
    let numeric: Option<Vec<f64>> = triples.iter().map(|t| t.val.parse::<f64>().ok()).collect();
    let vals = match numeric {
        Some(nums) => ValsInput::Num(nums),
        None => ValsInput::Str(triples.iter().map(|t| t.val.to_string()).collect()),
    };
    Assoc::try_new_with(rows, cols, vals, Aggregator::Last, par, KeyEncoding::Sort)
        .expect("scan triples are consistent")
}

#[test]
fn prop_scan_to_assoc_dict_matches_string_path() {
    check("scan→assoc dict == string path", 12, |g| {
        let numeric = g.rng().chance(0.5);
        let table = random_table(g.rng(), 300 + g.rng().below_usize(400), numeric);
        assert!(table.tablet_count() > 2, "need real tablet fan-out");
        // Offline flags gate writes, not reads — scans must not care.
        table.set_tablet_offline(0, true);
        let expect =
            triples_to_assoc_string_path(&table.scan(ScanRange::all()), Parallelism::serial());
        // Serial streaming (dict path, no Vec<Triple>) and parallel
        // fan-out at every thread count.
        assert_identical(
            &table.scan_to_assoc_par(ScanRange::all(), Parallelism::serial()),
            &expect,
            "serial stream",
        );
        for t in THREADS {
            assert_identical(
                &table.scan_to_assoc_par(ScanRange::all(), Parallelism::with_threads(t)),
                &expect,
                &format!("t={t}"),
            );
        }
        // A filtered, windowed stacked scan takes the same dict path.
        let spec = ScanSpec::over(ScanRange::all().with_cols("c05", "c20"));
        let filtered: Vec<Triple> = table.scan_spec(&spec);
        let expect_f = triples_to_assoc_string_path(&filtered, Parallelism::serial());
        for t in [1usize, 4] {
            assert_identical(
                &table.scan_spec_to_assoc(&spec, Parallelism::with_threads(t)),
                &expect_f,
                &format!("filtered t={t}"),
            );
        }
    });
}

/// The PR 3 TableMult ingestion, verbatim: owned strings, per-cell
/// binary search into the sorted distinct column list, then the same
/// SpGEMM — the string baseline the dict-encoded kernel must match.
/// **Frozen snapshot** — `benches/ablations.rs` carries its twin
/// (`table_mult_string_path`); change both together or not at all.
fn table_mult_string_baseline(a: &Table, b: &Table, s: &dyn Semiring) -> Vec<Triple> {
    struct Side {
        rows: Vec<String>,
        row_of: Vec<u32>,
        cols: Vec<String>,
        vals: Vec<f64>,
    }
    let ingest = |t: &Table| {
        let mut side =
            Side { rows: Vec::new(), row_of: Vec::new(), cols: Vec::new(), vals: Vec::new() };
        for tr in t.scan(ScanRange::all()) {
            if side.rows.last().map(String::as_str) != Some(tr.row.as_str()) {
                side.rows.push(tr.row.to_string());
            }
            side.row_of.push((side.rows.len() - 1) as u32);
            side.cols.push(tr.col.to_string());
            side.vals.push(tr.val.parse().unwrap_or(0.0));
        }
        side
    };
    let (sa, sb) = (ingest(a), ingest(b));
    if sa.rows.is_empty() && sb.rows.is_empty() {
        return Vec::new();
    }
    let mut merged: Vec<String> = sa.rows.iter().chain(&sb.rows).cloned().collect();
    merged.sort_unstable();
    merged.dedup();
    let to_csr = |side: &Side| {
        let mut distinct: Vec<String> = side.cols.clone();
        distinct.sort_unstable();
        distinct.dedup();
        let rows: Vec<usize> = side
            .row_of
            .iter()
            .map(|&own| {
                merged.binary_search(&side.rows[own as usize]).expect("row in merged set")
            })
            .collect();
        let cols: Vec<usize> = side
            .cols
            .iter()
            .map(|c| distinct.binary_search(c).expect("col in distinct set"))
            .collect();
        let m = CooMatrix::from_triples_aggregate(
            merged.len(),
            distinct.len(),
            &rows,
            &cols,
            &side.vals,
            0.0,
            |x, _| x,
        )
        .expect("scan triples are unique per (row, col)")
        .into_csr();
        (m, distinct)
    };
    let (ma, cols_a) = to_csr(&sa);
    let (mb, cols_b) = to_csr(&sb);
    let at = ma.transpose();
    let c = spgemm_par(&at, &mb, s, Parallelism::serial()).expect("shared row dimension");
    let mut out = Vec::new();
    for (i, c1) in cols_a.iter().enumerate() {
        let (cj, cv) = c.row(i);
        for (j, v) in cj.iter().zip(cv) {
            if *v != s.zero() {
                out.push(Triple::new(c1.as_str(), cols_b[*j as usize].as_str(), format_num(*v)));
            }
        }
    }
    out.sort();
    out
}

#[test]
fn prop_table_mult_dict_matches_string_baseline() {
    check("TableMult dict == string baseline", 8, |g| {
        let store = TableStore::new(TableConfig { split_threshold: 384, write_latency_us: 0 });
        let n = 120 + g.rng().below_usize(120);
        let rows: Vec<String> = (0..n).map(|_| format!("r{:02}", g.rng().below(24))).collect();
        let cols: Vec<String> = (0..n).map(|_| format!("c{:02}", g.rng().below(18))).collect();
        let vals: Vec<f64> = (0..n).map(|_| g.rng().range_i64(1, 9) as f64).collect();
        let a = Assoc::try_new(
            d4m::assoc::keys_from(&rows),
            d4m::assoc::keys_from(&cols),
            ValsInput::Num(vals),
            Aggregator::Last,
        )
        .unwrap();
        let (t, _) = store.ingest_assoc("edges", &a);
        assert!(t.tablet_count() > 1, "need split tables");
        for s in [&PlusTimes as &dyn Semiring, &MaxPlus] {
            let expect = table_mult_string_baseline(&t, &t, s);
            assert!(!expect.is_empty());
            for threads in [1usize, 2, 7] {
                let out = store.create_table(&format!("out_{}_{threads}", s.name()));
                let cells = graphulo::table_mult_par(
                    &t,
                    &t,
                    &out,
                    s,
                    Parallelism::with_threads(threads),
                );
                let got = out.scan(ScanRange::all());
                assert_eq!(got, expect, "{} t={threads}", s.name());
                assert_eq!(cells, expect.len(), "{} t={threads}", s.name());
            }
        }
    });
}

#[test]
fn shared_cells_survive_table_mutation() {
    // A scanned triple owns its bytes (shared, not borrowed): deleting
    // the cell from the table must not invalidate the scanned copy.
    let table = Table::new("t", TableConfig::default());
    table.write_batch(vec![Triple::new("r", "c", "hello")]).unwrap();
    let scanned = table.scan(ScanRange::all());
    assert!(table.delete("r", "c").unwrap());
    assert_eq!(scanned[0].val, "hello");
    assert_eq!(scanned[0].row, "r");
}
