//! Executable checks of the specific behaviors the paper's text
//! promises — each test cites the section it pins down.

use d4m::assoc::{Aggregator, Assoc, Key, Selector, Val, ValsInput, Values};
use d4m::semiring::{builtin, check_semiring_laws};

fn music() -> Assoc {
    Assoc::from_triples(
        &["0294.mp3", "0294.mp3", "0294.mp3", "1829.mp3", "1829.mp3", "1829.mp3", "7802.mp3",
            "7802.mp3", "7802.mp3"],
        &["artist", "duration", "genre", "artist", "duration", "genre", "artist", "duration",
            "genre"],
        &["Pink Floyd", "6:53", "rock", "Samuel Barber", "8:01", "classical", "Taylor Swift",
            "10:12", "pop"][..],
    )
}

/// §II.A / Fig 2: the four-attribute storage model, including the exact
/// sorted value pool and the 1-based index correspondence
/// `A[row[i], col[j]] = val[k] ⇔ adj[i,j] = k + 1`.
#[test]
fn fig2_storage_model_exact() {
    let a = music();
    let pool: Vec<&str> = a.values().strings().unwrap().iter().map(|s| s.as_ref()).collect();
    assert_eq!(
        pool,
        vec!["10:12", "6:53", "8:01", "Pink Floyd", "Samuel Barber", "Taylor Swift",
            "classical", "pop", "rock"]
    );
    // Fig 2's adj (1-based): [[4, 2, 9], [5, 3, 7], [6, 1, 8]].
    let expect = [[4.0, 2.0, 9.0], [5.0, 3.0, 7.0], [6.0, 1.0, 8.0]];
    for (i, row) in expect.iter().enumerate() {
        for (j, &k) in row.iter().enumerate() {
            assert_eq!(a.adj().get(i, j), Some(k), "adj[{i},{j}]");
        }
    }
}

/// §I.B: "zeroes are unstored" — for numbers, strings, and after
/// aggregation cancellation; keys of dropped entries vanish too.
#[test]
fn zeros_are_unstored_everywhere() {
    let num = Assoc::from_triples(&["a", "b"], &["x", "y"], vec![0.0, 1.0]);
    assert_eq!(num.shape(), (1, 1));
    let s = Assoc::from_triples(&["a", "b"], &["x", "y"], &["", "v"][..]);
    assert_eq!(s.shape(), (1, 1));
    let sum = Assoc::from_triples_agg(&["a", "a"], &["x", "x"], vec![5.0, -5.0], Aggregator::Sum);
    assert!(sum.is_empty());
}

/// §II.A: the empty associative array is stored as if numeric.
#[test]
fn empty_array_is_numeric() {
    assert!(Assoc::empty().is_numeric());
    // Ops producing empty results normalize to the canonical empty.
    let a = Assoc::from_triples(&["r"], &["c"], &["x"][..]);
    let b = Assoc::from_triples(&["q"], &["d"], &["y"][..]);
    let prod = a.elemmul(&b); // disjoint keys
    assert!(prod.is_empty() && prod.is_numeric());
    assert_eq!(prod, Assoc::empty());
}

/// §II.B item 1: string slices are inclusive on the right, unlike
/// Python ranges.
#[test]
fn string_slices_right_inclusive() {
    let a = music();
    let sel = a.select(&Selector::range("0294.mp3", "1829.mp3"), &Selector::All);
    assert!(sel.find_row(&Key::str("1829.mp3")).is_some(), "right endpoint included");
    // Position ranges stay right-EXclusive (Python semantics).
    let pos = a.select(&Selector::PosRange(0, 2), &Selector::All);
    assert_eq!(pos.row_keys().len(), 2);
}

/// §II.B item 2: integers in extraction are treated as indices of
/// `A.row`/`A.col`, not as key values.
#[test]
fn integers_are_positions_not_keys() {
    // Array whose keys ARE numbers 5, 6, 7 — positions 0, 1, 2.
    let a = Assoc::from_triples(&[5i64, 6, 7], &[1i64, 1, 1], 1.0);
    let by_pos = a.select(&Selector::Positions(vec![0]), &Selector::All);
    assert_eq!(by_pos.row_keys(), &[Key::num(5.0)]); // index 0 → key 5, not key 0
    let by_key = a.select(&Selector::keys(&[5i64]), &Selector::All);
    assert_eq!(by_pos, by_key);
}

/// §II.A: the aggregate parameter defaults to min and handles
/// collisions; the paper's examples use an associative, commutative op.
#[test]
fn constructor_default_min() {
    let a = Assoc::from_triples(&["r", "r"], &["c", "c"], vec![9.0, 4.0]);
    assert_eq!(a.get_num("r", "c"), Some(4.0));
    let s = Assoc::from_triples(&["r", "r"], &["c", "c"], &["zz", "aa"][..]);
    assert_eq!(s.get_str("r", "c"), Some("aa"));
}

/// §II.C.1: string addition concatenates colliding values; "any
/// collisions ... occur between a value from A and a value from B and
/// occur at most once for each pair of row and column keys."
#[test]
fn string_addition_concatenates() {
    let a = Assoc::from_triples(&["r"], &["c"], &["left"][..]);
    let b = Assoc::from_triples(&["r"], &["c"], &["right"][..]);
    assert_eq!((&a + &b).get_str("r", "c"), Some("leftright"));
}

/// §II.C.2: the mixed-type element-wise product asymmetry — string ×
/// numeric masks the string array, numeric × string reduces the string
/// operand via `.logical()` ("differs in its result").
#[test]
fn mixed_elemmul_asymmetry() {
    let s = music();
    let m = Assoc::from_triples(&["0294.mp3"], &["genre"], vec![7.0]);
    let masked = s.elemmul(&m); // string × numeric
    assert!(masked.is_string());
    assert_eq!(masked.get_str("0294.mp3", "genre"), Some("rock"));
    let reduced = m.elemmul(&s); // numeric × string
    assert!(reduced.is_numeric());
    assert_eq!(reduced.get_num("0294.mp3", "genre"), Some(7.0)); // 7 × logical(1)
}

/// §II.C.3: "associative array multiplication is currently defined only
/// for numerical associative arrays, so string associative arrays are
/// converted via the .logical() method prior."
#[test]
fn matmul_logicalizes_strings() {
    let s = music();
    let prod = s.transpose().matmul(&s);
    assert!(prod.is_numeric());
    assert_eq!(prod.get_num("artist", "artist"), Some(3.0));
}

/// §II.C.3: the product contracts over `A.col ∩ B.row` — keys outside
/// the intersection contribute nothing.
#[test]
fn matmul_contracts_intersection_only() {
    let a = Assoc::from_triples(&["r", "r"], &["shared", "only-a"], vec![2.0, 99.0]);
    let b = Assoc::from_triples(&["shared", "only-b"], &["c", "c"], vec![5.0, 99.0]);
    let c = a.matmul(&b);
    assert_eq!(c.get_num("r", "c"), Some(10.0));
    assert_eq!(c.nnz(), 1);
}

/// §II.C.1: condense removes empty rows/columns after addition (the
/// `good_rows`/`good_cols` indptr trick) — observable as key-space
/// shrinkage after cancellation.
#[test]
fn condense_after_cancellation() {
    let a = Assoc::from_triples(&["r1", "r2"], &["c1", "c2"], vec![3.0, 1.0]);
    let b = Assoc::from_triples(&["r1"], &["c1"], vec![-3.0]);
    let c = &a + &b;
    assert_eq!(c.shape(), (1, 1));
    assert_eq!(c.row_keys(), &[Key::str("r2")]);
    assert_eq!(c.col_keys(), &[Key::str("c2")]);
}

/// §I.A: every built-in value algebra satisfies the seven semiring
/// axioms the paper lists.
#[test]
fn paper_semiring_axioms() {
    for s in builtin() {
        check_semiring_laws(s.as_ref(), &[-3.0, -1.0, 0.0, 1.0, 2.0, 7.0]);
    }
}

/// §I.A: the string algebra (⊕ = min w.r.t. dictionary order, ⊗ =
/// concatenation, 0 = ε) drives element-wise ops on string arrays:
/// A*B under the string algebra's ⊕... the D4M implementation uses min
/// for `*` collisions; check min/concat behaviors explicitly.
#[test]
fn string_algebra_ops() {
    let a = Assoc::from_triples(&["r"], &["c"], &["beta"][..]);
    let b = Assoc::from_triples(&["r"], &["c"], &["alpha"][..]);
    assert_eq!(a.elemmul(&b).get_str("r", "c"), Some("alpha")); // min
    assert_eq!((&a + &b).get_str("r", "c"), Some("betaalpha")); // concat (A then B)
}

/// §II.A constructor form 2: `Assoc(row, col, val, adj=sp_mat)` — the
/// attribute-level constructor reproduces the same array.
#[test]
fn adj_constructor_form() {
    let a = music();
    let rebuilt = Assoc::from_parts(
        a.row_keys().to_vec(),
        a.col_keys().to_vec(),
        a.values().clone(),
        a.adj().clone(),
    )
    .unwrap();
    assert_eq!(rebuilt, a);
    // Numeric flag variant.
    let n = Assoc::from_triples(&["x"], &["y"], vec![2.0]);
    let rebuilt = Assoc::from_parts(
        n.row_keys().to_vec(),
        n.col_keys().to_vec(),
        Values::Numeric,
        n.adj().clone(),
    )
    .unwrap();
    assert_eq!(rebuilt, n);
}

/// §I.B: D4M value sets are entirely numeric or entirely string; the
/// constructor enforces this by construction (ValsInput is one or the
/// other), and operations yield consistently-typed results.
#[test]
fn value_type_consistency() {
    let s = music();
    assert!(s.is_string());
    assert!(s.logical().is_numeric());
    assert!(s.sqin().is_numeric());
    assert!(s.count(0).is_numeric());
    let masked = s.elemmul(&s.logical());
    assert!(masked.is_string());
    for (_, _, v) in masked.iter() {
        assert!(matches!(v, Val::Str(_)));
    }
}

/// The paper's Figure-1 tabular rendering round-trips through the
/// display path (headers + row keys + values all present).
#[test]
fn figure1_rendering() {
    let txt = music().to_string();
    for needle in ["artist", "duration", "genre", "0294.mp3", "Pink Floyd", "classical"] {
        assert!(txt.contains(needle), "missing {needle} in rendering");
    }
}

/// Broadcasting in the constructor: the paper's
/// `Assoc(rows, cols, 1)` scalar-value form.
#[test]
fn scalar_value_broadcast() {
    let a = Assoc::try_new(
        vec!["a".into(), "b".into()],
        vec!["x".into(), "y".into()],
        ValsInput::NumScalar(1.0),
        Aggregator::Min,
    )
    .unwrap();
    assert_eq!(a.nnz(), 2);
    assert!(a.iter().all(|(_, _, v)| v.as_num() == Some(1.0)));
}
