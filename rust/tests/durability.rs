//! Crash-injection harness for the durable storage tier (PR 6).
//!
//! The property under test is **prefix consistency**: whatever point a
//! crash cuts or corrupts the write-ahead log at, recovery must yield
//! exactly the table produced by replaying the *surviving prefix* of
//! log records into a fresh in-memory table — never a reordered,
//! partial-record, or resurrected state. With runs on disk (a minor
//! compaction happened before the crash) the covered prefix is the run
//! watermark or the surviving log prefix, whichever reaches further.
//!
//! The harness drives a deterministic workload through a durable
//! [`Table`], then mutilates a copy of its directory at every record
//! boundary, inside record headers, mid-payload, and with flipped
//! bytes, recovering each copy and comparing full scans against the
//! expected replay at several thread counts.

use d4m::store::wal;
use d4m::store::{FsyncPolicy, ScanRange, Table, TableConfig, Triple};
use d4m::util::{Parallelism, SplitMix64};
use std::path::{Path, PathBuf};

/// One logged operation of the workload (mirrors the WAL's op kinds).
#[derive(Debug, Clone)]
enum Op {
    Put(Vec<Triple>),
    Del(String, String),
}

fn apply(t: &Table, op: &Op) {
    match op {
        Op::Put(batch) => {
            t.write_batch(batch.clone()).expect("no offline tablets in harness");
        }
        Op::Del(r, c) => {
            t.delete(r, c).expect("no degraded tables in harness");
        }
    }
}

/// Deterministic mixed put/delete workload over a small keyspace, so
/// overwrites, deletes of live cells, and deletes of absent cells all
/// occur.
fn workload(seed: u64, n_ops: usize) -> Vec<Op> {
    let mut rng = SplitMix64::new(seed);
    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        if rng.chance(0.25) {
            ops.push(Op::Del(
                format!("r{:02}", rng.below(20)),
                format!("c{}", rng.below(4)),
            ));
        } else {
            let k = 1 + rng.below_usize(4);
            let batch = (0..k)
                .map(|_| {
                    Triple::new(
                        format!("r{:02}", rng.below(20)),
                        format!("c{}", rng.below(4)),
                        format!("v{}", rng.below(100)),
                    )
                })
                .collect();
            ops.push(Op::Put(batch));
        }
    }
    ops
}

/// Small split threshold so the workload exercises multi-tablet tables.
fn cfg() -> TableConfig {
    TableConfig { split_threshold: 256, write_latency_us: 0 }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("d4m-durability-tests")
        .join(format!("{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Copy every non-WAL file of a table directory (runs + manifest) into
/// a fresh directory, then install `wal_bytes` as its log — one
/// simulated crash image.
fn crash_image(base: &Path, dest: &Path, wal_bytes: &[u8]) {
    let _ = std::fs::remove_dir_all(dest);
    std::fs::create_dir_all(dest).unwrap();
    for entry in std::fs::read_dir(base).unwrap() {
        let entry = entry.unwrap();
        if entry.file_name() == "wal.log" {
            continue;
        }
        std::fs::copy(entry.path(), dest.join(entry.file_name())).unwrap();
    }
    std::fs::write(dest.join("wal.log"), wal_bytes).unwrap();
}

/// The expected table for a crash image: ops `0..covered` replayed
/// into a fresh in-memory table (`covered` = how many leading ops
/// survive, via runs or the log prefix).
fn expected_scan(ops: &[Op], covered: usize) -> Vec<Triple> {
    let t = Table::new("expect", cfg());
    for op in &ops[..covered] {
        apply(&t, op);
    }
    t.scan(ScanRange::all())
}

/// Recover one crash image and assert prefix consistency at several
/// scan thread counts, plus recovery idempotence (recovering the
/// already-recovered directory changes nothing).
fn check_image(dir: &Path, ops: &[Op], covered: usize, what: &str) {
    let expect = expected_scan(ops, covered);
    let r = Table::recover("t", cfg(), dir, FsyncPolicy::Never)
        .unwrap_or_else(|e| panic!("{what}: recovery failed: {e}"));
    assert_eq!(r.scan(ScanRange::all()), expect, "{what}: serial scan");
    for threads in [2usize, 4] {
        assert_eq!(
            r.scan_par(ScanRange::all(), Parallelism::with_threads(threads)),
            expect,
            "{what}: scan threads={threads}"
        );
    }
    drop(r);
    let r2 = Table::recover("t", cfg(), dir, FsyncPolicy::Never)
        .unwrap_or_else(|e| panic!("{what}: second recovery failed: {e}"));
    assert_eq!(r2.scan(ScanRange::all()), expect, "{what}: recovery not idempotent");
}

/// Run the full crash matrix for one workload: `compact_after` ops are
/// applied, then (optionally) a minor compaction, then the rest — and
/// the resulting directory is crashed at every record boundary, inside
/// headers, mid-payload, and with corrupted bytes.
fn crash_matrix(tag: &str, seed: u64, n_ops: usize, compact_after: Option<usize>) {
    let ops = workload(seed, n_ops);
    let root = temp_dir(tag);
    let base = root.join("base");
    {
        let t = Table::durable("t", cfg(), &base, FsyncPolicy::Never).unwrap();
        for (i, op) in ops.iter().enumerate() {
            if compact_after == Some(i) {
                t.minor_compact().unwrap();
            }
            apply(&t, op);
        }
        t.sync().unwrap();
    }
    // Ops produce one WAL record each with seqs 1..=n. A minor
    // compaction does NOT truncate the log (only recovery starts a
    // fresh one), so the log always holds every record; the runs'
    // watermark equals the number of ops frozen before the compaction.
    let watermark = compact_after.unwrap_or(0);
    let wal_path = base.join("wal.log");
    let bytes = std::fs::read(&wal_path).unwrap();
    let spans = wal::record_spans(&wal_path).unwrap();
    assert_eq!(spans.len(), ops.len(), "one record per op");

    let image = root.join("image");
    // Crash 0: log cut down to (and inside) the magic header.
    for cut in [0usize, 4, 8] {
        crash_image(&base, &image, &bytes[..cut.min(bytes.len())]);
        check_image(&image, &ops, watermark, &format!("{tag}: cut@{cut}"));
    }
    // Every record boundary and two interior points per record: just
    // inside the header, and mid-payload.
    for (i, &(off, len)) in spans.iter().enumerate() {
        let off = off as usize;
        let len = len as usize; // full record: 8-byte header + payload
        for (cut, label) in [
            (off + 2, "header"),
            (off + 8 + (len - 8) / 2, "payload"),
            (off + len, "boundary"),
        ] {
            // Cutting inside record i keeps records 0..i; cutting at
            // its end keeps it too. Runs cover the first `watermark`
            // ops regardless of the cut.
            let survivors = if cut >= off + len { i + 1 } else { i };
            crash_image(&base, &image, &bytes[..cut]);
            check_image(
                &image,
                &ops,
                survivors.max(watermark),
                &format!("{tag}: record {i} {label} cut@{cut}"),
            );
        }
    }
    // Corruption: flip one payload byte of a few records — replay must
    // stop cleanly at the damaged record, keeping the intact prefix.
    let mut rng = SplitMix64::new(seed ^ 0x5eed);
    for _ in 0..4.min(spans.len()) {
        let i = rng.below_usize(spans.len());
        let (off, len) = spans[i];
        let mut corrupt = bytes.clone();
        let at = off as usize + 8 + rng.below_usize(len as usize - 8);
        corrupt[at] ^= 0x40;
        crash_image(&base, &image, &corrupt);
        // The flipped payload byte fails the record's checksum (CRC-32
        // catches every single-byte error), so replay keeps 0..i and
        // everything after the damage is discarded.
        check_image(&image, &ops, i.max(watermark), &format!("{tag}: corrupt record {i}"));
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn crash_matrix_wal_only() {
    crash_matrix("wal-only", 0xD4_01, 28, None);
}

#[test]
fn crash_matrix_with_minor_compaction() {
    // Runs + manifest on disk, WAL covering all ops: whatever the cut,
    // recovery keeps at least the frozen prefix.
    crash_matrix("compacted", 0xD4_02, 26, Some(13));
}

#[test]
fn crash_matrix_compaction_at_tail() {
    // Freeze just before the last few ops: most cut points land below
    // the watermark, exercising the runs-win side of max(W, P).
    crash_matrix("tail-compacted", 0xD4_03, 20, Some(17));
}

#[test]
fn fsync_policies_roundtrip() {
    let ops = workload(0xD4_04, 15);
    let expect = expected_scan(&ops, ops.len());
    for (policy, tag) in [
        (FsyncPolicy::Never, "never"),
        (FsyncPolicy::Always, "always"),
        (FsyncPolicy::EveryN(3), "every3"),
    ] {
        let dir = temp_dir(&format!("fsync-{tag}"));
        {
            let t = Table::durable("t", cfg(), &dir, policy).unwrap();
            for op in &ops {
                apply(&t, op);
            }
            t.sync().unwrap();
        }
        let r = Table::recover("t", cfg(), &dir, policy).unwrap();
        assert_eq!(r.scan(ScanRange::all()), expect, "policy {tag}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn concurrent_writers_recover_completely() {
    // Four writers on disjoint row spaces through one durable table;
    // after sync + crash, recovery holds every acknowledged write (the
    // WAL lock serializes append+apply, so the log is a valid
    // interleaving whatever the thread schedule).
    use std::sync::Arc;
    let dir = temp_dir("concurrent");
    {
        let t = Arc::new(Table::durable("t", cfg(), &dir, FsyncPolicy::Never).unwrap());
        let mut handles = Vec::new();
        for w in 0..4 {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for i in 0..40 {
                    t.write_batch(vec![Triple::new(format!("w{w}-r{i:03}"), "c", "v")])
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        t.sync().unwrap();
    }
    let r = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
    assert_eq!(r.len(), 160);
    let all = r.scan(ScanRange::all());
    assert!(all.windows(2).all(|w| w[0] < w[1]), "recovered scan sorted+unique");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recovered_table_keeps_writing() {
    // Recovery hands back a live durable table: new writes land in the
    // fresh log and survive another crash-recover cycle.
    let dir = temp_dir("rewrite");
    {
        let t = Table::durable("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
        t.write_batch(vec![Triple::new("a", "c", "1")]).unwrap();
        t.sync().unwrap();
    }
    {
        let t = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
        t.write_batch(vec![Triple::new("b", "c", "2")]).unwrap();
        assert!(t.delete("a", "c").unwrap());
        t.sync().unwrap();
    }
    let r = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
    assert_eq!(r.get("a", "c"), None);
    assert_eq!(r.get("b", "c"), Some("2".into()));
    assert_eq!(r.len(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recovery_ignores_stray_files_in_table_dir() {
    // Only MANIFEST-listed runs are loaded; editor droppings and
    // orphaned tmp files in the directory must not affect recovery.
    let dir = temp_dir("stray");
    {
        let t = Table::durable("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
        t.write_batch(vec![Triple::new("a", "c", "1")]).unwrap();
        t.minor_compact().unwrap();
        t.sync().unwrap();
    }
    std::fs::write(dir.join("MANIFEST.tmp~"), b"junk").unwrap();
    std::fs::write(dir.join("run-99999999.run"), b"not a run file").unwrap();
    let r = Table::recover("t", cfg(), &dir, FsyncPolicy::Never).unwrap();
    assert_eq!(r.get("a", "c"), Some("1".into()));
    let _ = std::fs::remove_dir_all(&dir);
}
