//! Planner equivalence suite (PR 10).
//!
//! Contract under test: **plan-choice invariance**. Every physical
//! plan the planner can emit — every forced [`Choices`] combination,
//! at every thread count — writes bit-identical results to the naive
//! baseline (full multiply, mask enforced only at write-back; raw
//! scans with client-side aggregation). The planner moves work, never
//! results: masked/unmasked SpGEMM engines, row-restricted vs. full
//! ingest, filter-as-windows vs. filter-as-predicate vs. no pushdown,
//! combiner at scan vs. at merge, and every symbolic output bound must
//! all agree cell-for-cell.
//!
//! A final section pins `EXPLAIN` stability: re-planning an unchanged
//! workload renders the identical decision log.

use d4m::assoc::Assoc;
use d4m::graphulo::{
    bfs_planned, degree_table_planned, jaccard_seeded_planned, table_mult_masked_planned,
    table_mult_planned, table_mult_row_masked_planned,
};
use d4m::plan::{
    explain_mult, plan_mult, Choices, CombinerChoice, EngineChoice, FilterChoice, IngestChoice,
    MultNode, RowSetChoice,
};
use d4m::semiring::{MaxPlus, PlusTimes};
use d4m::sparse::SymbolicBound;
use d4m::store::{KeyMatch, ScanRange, Table, TableConfig, TableStore};
use d4m::util::Parallelism;
use std::sync::Arc;

/// Split-forcing store plus two overlapping operand tables; the `A`
/// side is minor-compacted so the planner's statistics see runs.
fn fixture() -> (TableStore, Arc<Table>, Arc<Table>) {
    let store = TableStore::new(TableConfig { split_threshold: 96, write_latency_us: 0 });
    let n = 150;
    let rows: Vec<String> = (0..n).map(|i| format!("r{:03}", i % 25)).collect();
    let cols: Vec<String> = (0..n).map(|i| format!("c{:03}", (i * 7) % 18)).collect();
    let (a, _) = store.ingest_assoc("a", &Assoc::from_triples(&rows, &cols, 2.0));
    let rows2: Vec<String> = (0..n).map(|i| format!("r{:03}", (i * 3) % 25)).collect();
    let cols2: Vec<String> = (0..n).map(|i| format!("c{:03}", (i * 5) % 18)).collect();
    let (b, _) = store.ingest_assoc("b", &Assoc::from_triples(&rows2, &cols2, 3.0));
    a.minor_compact().unwrap();
    (store, a, b)
}

/// The multiply-then-filter baseline: nothing pushed down, nothing
/// restricted, the mask applied at write-back only.
fn naive() -> Choices {
    Choices {
        ingest: IngestChoice::Full,
        filter: FilterChoice::NoPushdown,
        engine: EngineChoice::WriteFilter,
        bound: SymbolicBound::MinFlopsCols,
        ..Choices::frozen()
    }
}

#[test]
fn masked_mult_equivalent_over_full_forced_grid() {
    let (store, a, b) = fixture();
    let keep = KeyMatch::Prefix("c00".into());
    let base = store.create_table("base");
    let par1 = Parallelism::with_threads(1);
    let n = table_mult_masked_planned(&a, &b, &base, &PlusTimes, &keep, par1, &naive());
    let expect = base.scan(ScanRange::all());
    assert_eq!(n, expect.len());
    assert!(!expect.is_empty(), "degenerate fixture");
    let mut id = 0usize;
    for ingest in
        [IngestChoice::Cost, IngestChoice::Heuristic8x, IngestChoice::Ranges, IngestChoice::Full]
    {
        for filter in [
            FilterChoice::Cost,
            FilterChoice::Predicate,
            FilterChoice::Windows,
            FilterChoice::NoPushdown,
        ] {
            for engine in
                [EngineChoice::Cost, EngineChoice::MaskedSpGemm, EngineChoice::WriteFilter]
            {
                for bound in
                    [SymbolicBound::Auto, SymbolicBound::MinFlopsCols, SymbolicBound::Exact]
                {
                    let ch = Choices { ingest, filter, engine, bound, ..Choices::frozen() };
                    for threads in [1usize, 4] {
                        let out = store.create_table(&format!("g{id}"));
                        id += 1;
                        let par = Parallelism::with_threads(threads);
                        let cells =
                            table_mult_masked_planned(&a, &b, &out, &PlusTimes, &keep, par, &ch);
                        assert_eq!(out.scan(ScanRange::all()), expect, "{ch:?} t={threads}");
                        assert_eq!(cells, expect.len(), "{ch:?} t={threads}");
                    }
                }
            }
        }
    }
}

#[test]
fn row_masked_mult_equivalent_over_mask_shapes() {
    let (store, a, b) = fixture();
    let masks = [
        KeyMatch::Prefix("c00".into()),
        KeyMatch::Equals("c004".into()),
        KeyMatch::Glob("c*1".into()),
        KeyMatch::In((0..6).map(|i| format!("c{:03}", i * 3)).collect()),
    ];
    let forced_combo = Choices {
        filter: FilterChoice::Windows,
        engine: EngineChoice::WriteFilter,
        bound: SymbolicBound::Exact,
        ..Choices::planner()
    };
    let mut id = 0usize;
    for keep in masks {
        let base = store.create_table(&format!("rm_base_{id}"));
        let par1 = Parallelism::with_threads(1);
        table_mult_row_masked_planned(&a, &b, &base, &PlusTimes, &keep, par1, &naive());
        let expect = base.scan(ScanRange::all());
        for ch in [Choices::planner(), Choices::frozen(), forced_combo] {
            for threads in [1usize, 2, 7] {
                let out = store.create_table(&format!("rm_{id}"));
                id += 1;
                let par = Parallelism::with_threads(threads);
                let cells =
                    table_mult_row_masked_planned(&a, &b, &out, &PlusTimes, &keep, par, &ch);
                assert_eq!(out.scan(ScanRange::all()), expect, "{keep:?} {ch:?} t={threads}");
                assert_eq!(cells, expect.len(), "{keep:?} {ch:?} t={threads}");
            }
        }
    }
}

#[test]
fn unmasked_mult_ignores_choice_knobs() {
    let (store, a, b) = fixture();
    let base = store.create_table("um_base");
    table_mult_planned(&a, &b, &base, &MaxPlus, Parallelism::with_threads(1), &Choices::frozen());
    let expect = base.scan(ScanRange::all());
    assert!(!expect.is_empty());
    for (i, ch) in [Choices::planner(), Choices::frozen(), naive()].iter().enumerate() {
        for threads in [1usize, 4, 7] {
            let out = store.create_table(&format!("um_{i}_{threads}"));
            let par = Parallelism::with_threads(threads);
            let n = table_mult_planned(&a, &b, &out, &MaxPlus, par, ch);
            assert_eq!(out.scan(ScanRange::all()), expect, "{ch:?} t={threads}");
            assert_eq!(n, expect.len(), "{ch:?} t={threads}");
        }
    }
}

#[test]
fn degree_combiner_placements_identical() {
    let (store, a, _) = fixture();
    let base = store.create_table("deg_base");
    let n0 = degree_table_planned(&a, &base, Parallelism::with_threads(1), &Choices::frozen());
    let expect = base.scan(ScanRange::all());
    assert_eq!(n0, expect.len());
    assert!(!expect.is_empty());
    for comb in [CombinerChoice::Cost, CombinerChoice::AtScan, CombinerChoice::AtMerge] {
        for threads in [1usize, 2, 4, 7] {
            let ch = Choices { combiner: comb, ..Choices::planner() };
            let out = store.create_table(&format!("deg_{comb:?}_{threads}"));
            let n = degree_table_planned(&a, &out, Parallelism::with_threads(threads), &ch);
            assert_eq!(out.scan(ScanRange::all()), expect, "{comb:?} t={threads}");
            assert_eq!(n, expect.len(), "{comb:?} t={threads}");
        }
    }
}

#[test]
fn bfs_and_jaccard_rowset_lowerings_identical() {
    let store = TableStore::with_defaults();
    let n = 120;
    let rows: Vec<String> = (0..n).map(|i| format!("n{:03}", i % 40)).collect();
    let cols: Vec<String> = (0..n).map(|i| format!("n{:03}", (i * 7 + 1) % 40)).collect();
    let (t, _) = store.ingest_assoc("g", &Assoc::from_triples(&rows, &cols, 1.0));
    t.minor_compact().unwrap();
    let par1 = Parallelism::with_threads(1);
    // Seeds include an absent node; the frozen (range-set) lowering at
    // one thread is the baseline every other lowering must match.
    let seeds: Vec<String> = ["n000", "n013", "zzz"].iter().map(|s| s.to_string()).collect();
    let expect_bfs = bfs_planned(&t, &seeds, 4, par1, &Choices::frozen());
    let expect_probe = bfs_planned(&t, &seeds, 0, par1, &Choices::frozen());
    assert!(expect_bfs.iter().any(|hop| !hop.is_empty()));
    let nodes: Vec<String> = (0..12).map(|i| format!("n{:03}", i * 3)).collect();
    let expect_jac = jaccard_seeded_planned(&t, &nodes, par1, &Choices::frozen()).unwrap();
    for rowset in [RowSetChoice::Cost, RowSetChoice::Ranges, RowSetChoice::FilterIn] {
        let ch = Choices { rowset, ..Choices::planner() };
        for threads in [1usize, 2, 4, 7] {
            let par = Parallelism::with_threads(threads);
            assert_eq!(bfs_planned(&t, &seeds, 4, par, &ch), expect_bfs, "{rowset:?} t={threads}");
            assert_eq!(
                bfs_planned(&t, &seeds, 0, par, &ch),
                expect_probe,
                "{rowset:?} t={threads}"
            );
            assert_eq!(
                jaccard_seeded_planned(&t, &nodes, par, &ch).unwrap(),
                expect_jac,
                "{rowset:?} t={threads}"
            );
        }
    }
}

#[test]
fn explain_is_stable_and_decision_complete() {
    let (_store, a, b) = fixture();
    let node = MultNode::col_masked(&a, &b, KeyMatch::Prefix("c00".into()));
    let text = explain_mult(&plan_mult(&node, &Choices::planner()));
    // Re-planning an unchanged workload renders the identical string.
    assert_eq!(explain_mult(&plan_mult(&node, &Choices::planner())), text);
    for knob in ["mask: cols", "A: cells=", "B: cells=", "filter:", "ingest:", "engine:", "bound:"]
    {
        assert!(text.contains(knob), "missing {knob:?} in\n{text}");
    }
    // Forced plans record their provenance.
    assert!(explain_mult(&plan_mult(&node, &Choices::frozen())).contains("[forced]"));
}
