//! Concurrency stress tests for the thread pool and the ingest
//! pipeline: saturate bounded queues well past their depth, assert no
//! deadlock (every body runs under a watchdog so a hang fails fast
//! instead of wedging CI), every job executes exactly once, and the
//! pipeline's queue-full stall accounting fires under a tiny
//! `queue_depth`.

use d4m::assoc::{Aggregator, Assoc, ValsInput};
use d4m::bench::Workload;
use d4m::pipeline::{IngestPipeline, PipelineConfig};
use d4m::store::{Table, TableConfig, Triple, WriterConfig};
use d4m::util::{Parallelism, ThreadPool};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Run `body` on a helper thread and fail fast if it exceeds
/// `timeout` — a deadlock shows up as a clean test failure, not a hung
/// test runner. Generous bounds: these bodies finish in well under a
/// second on any machine; the timeout only trips on a real hang.
fn with_watchdog(name: &str, timeout: Duration, body: impl FnOnce() + Send + 'static) {
    let handle = std::thread::Builder::new()
        .name(format!("stress-{name}"))
        .spawn(body)
        .expect("spawn stress body");
    let start = Instant::now();
    while !handle.is_finished() {
        assert!(
            start.elapsed() <= timeout,
            "{name}: suspected deadlock — still running after {timeout:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.join().unwrap_or_else(|e| std::panic::resume_unwind(e));
}

#[test]
fn pool_saturation_runs_every_job_exactly_once() {
    with_watchdog("pool-saturation", Duration::from_secs(60), || {
        // 2 workers → bounded queue of 8 jobs; submit 10 000 so the
        // producer repeatedly blocks on a full queue.
        let pool = ThreadPool::new(2);
        let n_jobs = 10_000usize;
        let per_job: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n_jobs).map(|_| AtomicUsize::new(0)).collect());
        for i in 0..n_jobs {
            let per_job = Arc::clone(&per_job);
            pool.execute(move || {
                per_job[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.join();
        assert_eq!(pool.jobs_executed(), n_jobs);
        assert_eq!(pool.jobs_panicked(), 0);
        for (i, c) in per_job.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "job {i} ran a wrong number of times");
        }
    });
}

#[test]
fn pool_saturation_from_many_producers() {
    with_watchdog("pool-multi-producer", Duration::from_secs(60), || {
        let pool = Arc::new(ThreadPool::new(2));
        let counter = Arc::new(AtomicUsize::new(0));
        let producers: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let counter = Arc::clone(&counter);
                std::thread::spawn(move || {
                    for _ in 0..2_500 {
                        let counter = Arc::clone(&counter);
                        pool.execute(move || {
                            counter.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().expect("producer panicked");
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 10_000);
        assert_eq!(pool.jobs_executed(), 10_000);
    });
}

#[test]
fn concurrent_parallel_kernels_share_the_global_pool() {
    // Several threads running parallel matmuls at once must neither
    // deadlock the shared pool nor corrupt each other's chunk slots.
    with_watchdog("concurrent-kernels", Duration::from_secs(120), || {
        let w = Workload::generate(8, 0x5A5A);
        let a = Arc::new(
            Assoc::try_new_par(
                w.rows.iter().map(|s| s.as_str().into()).collect(),
                w.cols.iter().map(|s| s.as_str().into()).collect(),
                ValsInput::Num(w.num_vals.clone()),
                Aggregator::Min,
                Parallelism::serial(),
            )
            .unwrap(),
        );
        let expect = Arc::new(a.matmul_par(&a, Parallelism::serial()));
        let runners: Vec<_> = (0..4)
            .map(|_| {
                let a = Arc::clone(&a);
                let expect = Arc::clone(&expect);
                std::thread::spawn(move || {
                    for t in [2usize, 4, 7] {
                        let got = a.matmul_par(&a, Parallelism::with_threads(t));
                        assert_eq!(got, *expect, "concurrent matmul t={t}");
                    }
                })
            })
            .collect();
        for r in runners {
            r.join().expect("kernel runner panicked");
        }
    });
}

#[test]
fn pipeline_tiny_queue_counts_stalls_and_loses_nothing() {
    with_watchdog("pipeline-backpressure", Duration::from_secs(120), || {
        // Slow table writes + queue_depth 1 + tiny write buffer: the
        // producer must hit the queue-full path many times, and every
        // triple must still land exactly once.
        let table = Arc::new(Table::new(
            "t",
            TableConfig { split_threshold: 1 << 16, write_latency_us: 200 },
        ));
        let mut p = IngestPipeline::start(
            Arc::clone(&table),
            PipelineConfig {
                workers: 2,
                queue_depth: 1,
                writer: WriterConfig { batch_bytes: 256, ..Default::default() },
                ..Default::default()
            },
        );
        let n = 4_000usize;
        p.submit_all((0..n).map(|i| Triple::new(format!("row{i:06}"), "c", "v")));
        let report = p.finish();
        assert_eq!(report.submitted, n);
        assert_eq!(report.written, n, "no triple may be dropped or duplicated");
        assert!(report.stalls > 0, "tiny queue must produce queue-full stalls");
        assert_eq!(table.len(), n);
    });
}
