//! Equivalence suite for the server-side iterator stack.
//!
//! Contract under test: every stacked scan — any *set* of ranges
//! (including overlapping row spans and distinct column windows),
//! filter stages, and a per-row combiner, at any thread count, streamed
//! or collected, across tablet splits and offline tablets — is
//! **byte-identical** to the naive client-side pipeline: materialize
//! each range in full, take the sorted-dedup union, then filter, then
//! reduce.

use d4m::store::{
    format_num, lock_acquisitions, CellFilter, CompactionSpec, DurableOptions, FsyncPolicy,
    KeyMatch, RowReduce, ScanIter, ScanRange, ScanSpec, SharedStr, Table, TableConfig, Triple,
};
use d4m::util::prop::check;
use d4m::util::{Parallelism, SplitMix64};

const THREADS: [usize; 3] = [2, 4, 7];

/// The reference implementation: each range materialized by a plain
/// row scan, client-side column window per range, sorted-dedup union
/// across ranges, then filters and row reduction — exactly what the
/// stack is supposed to push into the tablets.
fn naive(table: &Table, spec: &ScanSpec) -> Vec<Triple> {
    let mut cells: Vec<Triple> = Vec::new();
    for range in &spec.ranges {
        let rows_only = ScanRange {
            lo: range.lo.clone(),
            hi: range.hi.clone(),
            ..ScanRange::default()
        };
        cells.extend(table.scan_par(rows_only, Parallelism::serial()).into_iter().filter(
            |t| {
                range.col_lo.as_deref().is_none_or(|lo| t.col.as_str() >= lo)
                    && range.col_hi.as_deref().is_none_or(|hi| t.col.as_str() < hi)
            },
        ));
    }
    // Sorted-dedup union of the per-range results (cells are unique per
    // (row, col); a cell caught by two ranges appears once).
    cells.sort();
    cells.dedup_by(|x, y| x.row == y.row && x.col == y.col);
    let mut cells: Vec<Triple> =
        cells.into_iter().filter(|t| spec.filters.iter().all(|f| f.matches(t))).collect();
    let Some(reduce) = &spec.reduce else {
        return cells;
    };
    let mut out = Vec::new();
    let mut cur: Option<(SharedStr, usize, f64)> = None;
    let emit = |row: SharedStr, count: usize, acc: f64, out: &mut Vec<Triple>| {
        let (col, val) = match reduce {
            RowReduce::Count { out_col } => (out_col.clone(), count.to_string()),
            RowReduce::Sum { out_col }
            | RowReduce::Min { out_col }
            | RowReduce::Max { out_col } => (out_col.clone(), format_num(acc)),
        };
        out.push(Triple::new(row, col, val));
    };
    for t in cells.drain(..) {
        let v: f64 = t.val.parse().unwrap_or(0.0);
        match &mut cur {
            Some((row, count, acc)) if *row == t.row => {
                *count += 1;
                match reduce {
                    RowReduce::Count { .. } => {}
                    RowReduce::Sum { .. } => *acc += v,
                    RowReduce::Min { .. } => *acc = acc.min(v),
                    RowReduce::Max { .. } => *acc = acc.max(v),
                }
            }
            _ => {
                if let Some((row, count, acc)) = cur.take() {
                    emit(row, count, acc, &mut out);
                }
                cur = Some((t.row, 1, v));
            }
        }
    }
    if let Some((row, count, acc)) = cur {
        emit(row, count, acc, &mut out);
    }
    out
}

/// Random table with many tablets (small split threshold, many small
/// batches so splits actually trigger).
fn random_table(rng: &mut SplitMix64, cells: usize) -> Table {
    let table = Table::new("t", TableConfig { split_threshold: 512, write_latency_us: 0 });
    let triples: Vec<Triple> = (0..cells)
        .map(|_| {
            Triple::new(
                format!("r{:03}", rng.below(120)),
                format!("c{:02}", rng.below(24)),
                format!("{}", rng.range_i64(-50, 100)),
            )
        })
        .collect();
    for chunk in triples.chunks(16) {
        table.write_batch(chunk.to_vec()).unwrap();
    }
    table
}

/// One random range: half the time row-bounded, half the time a column
/// window on top.
fn random_range(rng: &mut SplitMix64) -> ScanRange {
    let mut range = if rng.chance(0.5) {
        let lo = rng.below(120);
        let hi = lo + 1 + rng.below(120 - lo);
        ScanRange::rows(format!("r{lo:03}"), format!("r{hi:03}"))
    } else {
        ScanRange::all()
    };
    if rng.chance(0.5) {
        let lo = rng.below(24);
        let hi = lo + 1 + rng.below(24 - lo);
        range = range.with_cols(format!("c{lo:02}"), format!("c{hi:02}"));
    }
    range
}

fn random_spec(rng: &mut SplitMix64) -> ScanSpec {
    // A third of the specs carry a multi-range set: point ranges, row
    // spans, and windowed ranges, freely overlapping.
    let mut spec = if rng.chance(0.33) {
        let k = 1 + rng.below_usize(6);
        let mut ranges = Vec::with_capacity(k);
        for _ in 0..k {
            if rng.chance(0.4) {
                ranges.push(ScanRange::single(format!("r{:03}", rng.below(120))));
            } else {
                ranges.push(random_range(rng));
            }
        }
        ScanSpec::ranges(ranges)
    } else {
        ScanSpec::over(random_range(rng))
    };
    if rng.chance(0.4) {
        let matcher = match rng.below(4) {
            0 => KeyMatch::Prefix("c1".into()),
            1 => KeyMatch::Glob("c*1".into()),
            2 => KeyMatch::Glob("c?2".into()),
            _ => KeyMatch::In(
                ["c03", "c07", "c11", "c19"].iter().map(|s| s.to_string()).collect(),
            ),
        };
        spec = spec.filtered(CellFilter::col(matcher));
    }
    if rng.chance(0.3) {
        spec = spec.filtered(CellFilter::row(KeyMatch::Glob("r*1".into())));
    }
    if rng.chance(0.2) {
        spec = spec.filtered(CellFilter::val(KeyMatch::Glob("-*".into())));
    }
    if rng.chance(0.4) {
        spec = spec.reduced(match rng.below(4) {
            0 => RowReduce::Count { out_col: "n".into() },
            1 => RowReduce::Sum { out_col: "s".into() },
            2 => RowReduce::Min { out_col: "lo".into() },
            _ => RowReduce::Max { out_col: "hi".into() },
        });
    }
    if rng.chance(0.5) {
        // Batch hints move lock/copy granularity only — results must
        // stay byte-identical (including hints past the clamp range).
        spec = spec.batched(1 + rng.below_usize(4000));
    }
    spec
}

#[test]
fn prop_stacked_scan_equals_naive_pipeline() {
    check("stacked scan == naive scan-filter-reduce", 40, |g| {
        let cells = 300 + g.rng().below_usize(500);
        let table = random_table(g.rng(), cells);
        assert!(table.tablet_count() > 2, "need real tablet fan-out");
        let spec = random_spec(g.rng());
        let expect = naive(&table, &spec);
        // Serial collect, parallel collect at several thread counts,
        // and the streaming iterator must all agree byte-for-byte.
        assert_eq!(
            expect,
            table.scan_spec_par(&spec, Parallelism::serial()),
            "serial stack vs naive ({spec:?})"
        );
        for t in THREADS {
            assert_eq!(
                expect,
                table.scan_spec_par(&spec, Parallelism::with_threads(t)),
                "parallel stack t={t} ({spec:?})"
            );
        }
        let streamed: Vec<Triple> = table.scan_stream(spec.clone()).collect();
        assert_eq!(expect, streamed, "streamed stack ({spec:?})");
    });
}

#[test]
fn prop_scan_to_assoc_streams_identically() {
    check("scan_spec_to_assoc streaming == collected", 15, |g| {
        let table = random_table(g.rng(), 400);
        let spec = random_spec(g.rng());
        let serial = table.scan_spec_to_assoc(&spec, Parallelism::serial());
        for t in THREADS {
            let par = table.scan_spec_to_assoc(&spec, Parallelism::with_threads(t));
            assert_eq!(serial, par, "scan_spec_to_assoc t={t}");
        }
    });
}

#[test]
fn stacked_scan_ignores_offline_flags_like_naive() {
    // Reads are served regardless of the offline flag (it gates
    // writes); the stack must behave exactly like the naive scan when
    // tablets are marked offline mid-table.
    let mut rng = SplitMix64::new(0x0FF_715);
    let table = random_table(&mut rng, 600);
    let tablets = table.tablet_count();
    assert!(tablets > 3);
    table.set_tablet_offline(1, true);
    table.set_tablet_offline(tablets - 1, true);
    let spec = ScanSpec::all()
        .filtered(CellFilter::col(KeyMatch::Prefix("c0".into())))
        .reduced(RowReduce::Count { out_col: "n".into() });
    let expect = naive(&table, &spec);
    assert!(!expect.is_empty());
    for t in [1, 2, 4, 7] {
        assert_eq!(expect, table.scan_spec_par(&spec, Parallelism::with_threads(t)));
    }
    let streamed: Vec<Triple> = table.scan_stream(spec).collect();
    assert_eq!(expect, streamed);
}

#[test]
fn stream_seek_is_absolute_and_bidirectional() {
    let mut rng = SplitMix64::new(42);
    let table = random_table(&mut rng, 500);
    let all = table.scan(ScanRange::all());
    let mut stream = table.scan_stream(ScanSpec::all());
    // Forward seek to each of a few sorted positions, then a backward
    // seek; each must land exactly on the first key >= target.
    for target in ["r020", "r050", "r110", "r030"] {
        stream.seek(target, "");
        let got = stream.next_triple();
        let expect = all.iter().find(|t| t.row.as_str() >= target).cloned();
        assert_eq!(got, expect, "seek({target})");
    }
}

#[test]
fn seek_respects_range_clamp() {
    let mut rng = SplitMix64::new(7);
    let table = random_table(&mut rng, 300);
    let range = ScanRange::rows("r040", "r080");
    let in_range = table.scan(range.clone());
    let mut stream = table.scan_stream(ScanSpec::over(range));
    // Seeking before the range start clamps to it...
    stream.seek("r000", "");
    assert_eq!(stream.next_triple().as_ref(), in_range.first());
    // ...and seeking past the range end exhausts the stream.
    stream.seek("r099", "");
    assert_eq!(stream.next_triple(), None);
}

// ---------------------------------------------------------------------
// Multi-range (BatchScanner) section
// ---------------------------------------------------------------------

#[test]
fn prop_multirange_scan_equals_union_of_per_range_scans() {
    // The PR 5 contract: a stacked multi-range scan is byte-identical
    // to the sorted-dedup union of the equivalent single-range stacked
    // scans — across splits, filter stacks, batch hints, and every
    // thread count, streamed or collected.
    check("multi-range scan == sorted-dedup union of per-range scans", 30, |g| {
        let cells = 300 + g.rng().below_usize(500);
        let table = random_table(g.rng(), cells);
        assert!(table.tablet_count() > 2, "need real tablet fan-out");
        let k = 1 + g.rng().below_usize(7);
        let mut ranges = Vec::with_capacity(k);
        for _ in 0..k {
            if g.rng().chance(0.5) {
                ranges.push(ScanRange::single(format!("r{:03}", g.rng().below(120))));
            } else {
                ranges.push(random_range(g.rng()));
            }
        }
        let mut filters = Vec::new();
        if g.rng().chance(0.4) {
            filters.push(CellFilter::row(KeyMatch::Glob("r*1".into())));
        }
        if g.rng().chance(0.3) {
            filters.push(CellFilter::val(KeyMatch::Glob("-*".into())));
        }
        // Union of the single-range stacked scans (same filter stack).
        let mut expect: Vec<Triple> = Vec::new();
        for r in &ranges {
            let mut single = ScanSpec::over(r.clone());
            single.filters = filters.clone();
            expect.extend(table.scan_spec_par(&single, Parallelism::serial()));
        }
        expect.sort();
        expect.dedup_by(|x, y| x.row == y.row && x.col == y.col);
        // One stacked multi-range scan, every consumption mode.
        let mut spec = ScanSpec::ranges(ranges);
        spec.filters = filters;
        if g.rng().chance(0.5) {
            spec = spec.batched(1 + g.rng().below_usize(4000));
        }
        assert_eq!(expect, table.scan_spec_par(&spec, Parallelism::serial()), "serial");
        for t in THREADS {
            assert_eq!(
                expect,
                table.scan_spec_par(&spec, Parallelism::with_threads(t)),
                "threads={t}"
            );
        }
        let streamed: Vec<Triple> = table.scan_stream(spec.clone()).collect();
        assert_eq!(expect, streamed, "streamed");
        // And the generalized naive pipeline agrees (window per range).
        assert_eq!(expect, naive(&table, &spec), "naive union");
    });
}

#[test]
fn prop_multirange_stacks_with_combiners() {
    // Combiners fold the *union*: a row split across two ranges with
    // different column windows aggregates once, over the union of its
    // in-window cells.
    check("multi-range scan + combiner == naive union-reduce", 20, |g| {
        let table = random_table(g.rng(), 500);
        let k = 2 + g.rng().below_usize(4);
        let ranges: Vec<ScanRange> = (0..k).map(|_| random_range(g.rng())).collect();
        let mut spec = ScanSpec::ranges(ranges).reduced(match g.rng().below(4) {
            0 => RowReduce::Count { out_col: "n".into() },
            1 => RowReduce::Sum { out_col: "s".into() },
            2 => RowReduce::Min { out_col: "lo".into() },
            _ => RowReduce::Max { out_col: "hi".into() },
        });
        if g.rng().chance(0.4) {
            spec = spec.filtered(CellFilter::col(KeyMatch::Prefix("c1".into())));
        }
        let expect = naive(&table, &spec);
        assert_eq!(expect, table.scan_spec_par(&spec, Parallelism::serial()), "serial");
        for t in THREADS {
            assert_eq!(
                expect,
                table.scan_spec_par(&spec, Parallelism::with_threads(t)),
                "threads={t}"
            );
        }
        let streamed: Vec<Triple> = table.scan_stream(spec.clone()).collect();
        assert_eq!(expect, streamed, "streamed");
    });
}

#[test]
fn multirange_scan_ignores_offline_flags_like_naive() {
    // Offline gates writes only; a multi-range scan must read through
    // offline tablets exactly like the naive union.
    let mut rng = SplitMix64::new(0x0FF_716);
    let table = random_table(&mut rng, 600);
    let tablets = table.tablet_count();
    assert!(tablets > 3);
    table.set_tablet_offline(0, true);
    table.set_tablet_offline(tablets / 2, true);
    let spec = ScanSpec::ranges([
        ScanRange::rows("r000", "r030"),
        ScanRange::rows("r050", "r080").with_cols("c05", "c15"),
        ScanRange::single("r100"),
    ])
    .filtered(CellFilter::col(KeyMatch::Prefix("c0".into())));
    let expect = naive(&table, &spec);
    assert!(!expect.is_empty());
    for t in [1, 2, 4, 7] {
        assert_eq!(expect, table.scan_spec_par(&spec, Parallelism::with_threads(t)));
    }
    let streamed: Vec<Triple> = table.scan_stream(spec).collect();
    assert_eq!(expect, streamed);
}

#[test]
fn multirange_stream_survives_mid_scan_split() {
    let table = Table::new("t", TableConfig { split_threshold: 512, write_latency_us: 0 });
    for i in 0..60 {
        table
            .write_batch(vec![Triple::new(format!("a{i:03}"), "c", "v")])
            .unwrap();
    }
    // Ranges over the existing prefix and one that only fills later.
    let spec = ScanSpec::ranges([
        ScanRange::rows("a000", "a020"),
        ScanRange::rows("z000", "z040"),
    ]);
    let mut s = table.scan_stream(spec.clone());
    let mut got = Vec::new();
    for _ in 0..5 {
        got.push(s.next_triple().unwrap());
    }
    // Grow the table across more split points while the stream is open;
    // the cursor re-locates by key and hops into the late range.
    table
        .write_batch((0..40).map(|i| Triple::new(format!("z{i:03}"), "c", "v")).collect())
        .unwrap();
    for tr in s {
        got.push(tr);
    }
    assert!(got.windows(2).all(|w| w[0] < w[1]), "stream stays sorted");
    assert_eq!(got.iter().filter(|t| t.row.starts_with('a')).count(), 20);
    assert_eq!(got.iter().filter(|t| t.row.starts_with('z')).count(), 40);
    // A fresh scan agrees with the naive union on the final state.
    assert_eq!(table.scan_spec(&spec), naive(&table, &spec));
}

#[test]
fn multirange_seek_lands_on_next_range() {
    let mut rng = SplitMix64::new(99);
    let table = random_table(&mut rng, 400);
    let spec = ScanSpec::ranges([
        ScanRange::rows("r010", "r020"),
        ScanRange::rows("r060", "r070"),
    ]);
    let expect = naive(&table, &spec);
    let mut stream = table.scan_stream(spec);
    // Seek into the gap: the stream resumes at the second range.
    stream.seek("r040", "");
    let got = stream.next_triple();
    let gap_expect = expect.iter().find(|t| t.row.as_str() >= "r060").cloned();
    assert_eq!(got, gap_expect);
    // Seek before everything clamps to the set start.
    stream.seek("", "");
    assert_eq!(stream.next_triple().as_ref(), expect.first());
    // Seek past everything exhausts.
    stream.seek("r999", "");
    assert_eq!(stream.next_triple(), None);
}

#[test]
fn filtered_scan_across_many_tablets_and_batches() {
    // Deterministic layout: every row holds the full column set, so the
    // expected windowed output is easy to state in closed form.
    let table = Table::new("t", TableConfig { split_threshold: 384, write_latency_us: 0 });
    for i in 0..150 {
        let batch: Vec<Triple> = (0..6)
            .map(|c| Triple::new(format!("row{i:03}"), format!("c{c}"), format!("{}", i * 10 + c)))
            .collect();
        table.write_batch(batch).unwrap();
    }
    assert!(table.tablet_count() > 4);
    let spec = ScanSpec::over(ScanRange::rows("row010", "row140").with_cols("c2", "c5"));
    let expect_rows = 130usize;
    let got = table.scan_spec(&spec);
    assert_eq!(got.len(), expect_rows * 3);
    assert!(got.iter().all(|t| t.col.as_str() >= "c2" && t.col.as_str() < "c5"));
    assert!(got.windows(2).all(|w| w[0] < w[1]));
    // The reduced form: one sum per row over the window.
    let reduced = table.scan_spec(
        &ScanSpec::over(ScanRange::rows("row010", "row140").with_cols("c2", "c5"))
            .reduced(RowReduce::Sum { out_col: "s".into() }),
    );
    assert_eq!(reduced.len(), expect_rows);
    // row010 window = 102 + 103 + 104.
    assert_eq!(reduced[0], Triple::new("row010", "s", "309"));
}

// ---------------------------------------------------------------------
// Compaction equivalence section (PR 6)
// ---------------------------------------------------------------------
//
// Contract: storage tiering is invisible to every reader. A table whose
// cells are spread over memtable + tombstones + frozen runs scans
// byte-identically — under any range set, filter/combiner stack, batch
// hint, thread count, streamed or collected — to a mirror table holding
// the same logical cells entirely in memory. And a combiner applied at
// *merge* time (major compaction) is bit-identical to the same combiner
// applied at *scan* time, for every `RowReduce`.

/// Build two tables with identical logical content from one op stream:
/// `tiered` gets minor compactions (and occasionally a logically-
/// invisible major compaction) interleaved with the writes, plus
/// deletes that land as tombstones over its runs; `flat` applies the
/// same puts and deletes purely in memory. Also asserts the two
/// tables' `delete` return values agree — the tombstone path must
/// report visible-before exactly like the memtable path.
fn mirrored_tables(rng: &mut SplitMix64, cells: usize) -> (Table, Table) {
    let cfg = TableConfig { split_threshold: 512, write_latency_us: 0 };
    let tiered = Table::new("tiered", cfg.clone());
    let flat = Table::new("flat", cfg);
    let triples: Vec<Triple> = (0..cells)
        .map(|_| {
            Triple::new(
                format!("r{:03}", rng.below(120)),
                format!("c{:02}", rng.below(24)),
                format!("{}", rng.range_i64(-50, 100)),
            )
        })
        .collect();
    let chunks: Vec<&[Triple]> = triples.chunks(16).collect();
    let mid = chunks.len() / 2;
    for (i, chunk) in chunks.iter().enumerate() {
        tiered.write_batch(chunk.to_vec()).unwrap();
        flat.write_batch(chunk.to_vec()).unwrap();
        // One guaranteed freeze at the midpoint plus random ones, so
        // the memtable always layers over at least one run.
        if i == mid || rng.chance(0.15) {
            tiered.minor_compact().unwrap();
        }
        if rng.chance(0.08) {
            tiered.major_compact(&CompactionSpec::default()).unwrap();
        }
        if rng.chance(0.4) {
            let row = format!("r{:03}", rng.below(120));
            let col = format!("c{:02}", rng.below(24));
            let a = tiered.delete(&row, &col).unwrap();
            let b = flat.delete(&row, &col).unwrap();
            assert_eq!(a, b, "delete({row},{col}) visibility must not depend on tiering");
        }
    }
    (tiered, flat)
}

#[test]
fn prop_tiered_scan_equals_flat_scan() {
    check("memtable+runs stacked scan == all-in-memory scan", 25, |g| {
        let cells = 300 + g.rng().below_usize(400);
        let (tiered, flat) = mirrored_tables(g.rng(), cells);
        assert!(tiered.run_count() > 0, "need a real run stack");
        assert_eq!(tiered.len(), flat.len(), "merged len counts visible cells once");
        let spec = random_spec(g.rng());
        let expect = flat.scan_spec_par(&spec, Parallelism::serial());
        assert_eq!(expect, tiered.scan_spec_par(&spec, Parallelism::serial()), "serial");
        for t in THREADS {
            assert_eq!(
                expect,
                tiered.scan_spec_par(&spec, Parallelism::with_threads(t)),
                "threads={t} ({spec:?})"
            );
        }
        let streamed: Vec<Triple> = tiered.scan_stream(spec.clone()).collect();
        assert_eq!(expect, streamed, "streamed ({spec:?})");
        // The naive pipeline over the tiered table agrees too (its row
        // scans walk the same merged cursor).
        assert_eq!(naive(&tiered, &spec), naive(&flat, &spec), "naive over tiered");
    });
}

#[test]
fn prop_tiered_multirange_with_offline_tablets() {
    // Multi-range sets + offline tablets over the layer stack: offline
    // gates writes only, and range pruning must clamp run cursors to
    // tablet extents (post-split tablets share runs — without the
    // clamp, cells would be served twice).
    check("tiered multi-range scan across offline tablets", 15, |g| {
        let (tiered, flat) = mirrored_tables(g.rng(), 500);
        assert!(tiered.tablet_count() > 2, "need post-split shared runs");
        tiered.set_tablet_offline(0, true);
        tiered.set_tablet_offline(tiered.tablet_count() / 2, true);
        let k = 2 + g.rng().below_usize(5);
        let mut ranges = Vec::with_capacity(k);
        for _ in 0..k {
            if g.rng().chance(0.4) {
                ranges.push(ScanRange::single(format!("r{:03}", g.rng().below(120))));
            } else {
                ranges.push(random_range(g.rng()));
            }
        }
        let mut spec = ScanSpec::ranges(ranges);
        if g.rng().chance(0.5) {
            spec = spec.filtered(CellFilter::col(KeyMatch::Prefix("c1".into())));
        }
        let expect = flat.scan_spec_par(&spec, Parallelism::serial());
        for t in [1, 2, 4, 7] {
            assert_eq!(
                expect,
                tiered.scan_spec_par(&spec, Parallelism::with_threads(t)),
                "threads={t}"
            );
        }
        let streamed: Vec<Triple> = tiered.scan_stream(spec).collect();
        assert_eq!(expect, streamed, "streamed");
    });
}

#[test]
fn stream_survives_mid_scan_compactions() {
    // A stream holds no lock between blocks and re-locates by key, so
    // minor and major compactions may land mid-scan without the stream
    // skipping, duplicating, or reordering a single cell.
    let mut rng = SplitMix64::new(0xC0_46);
    let table = random_table(&mut rng, 500);
    let expect = table.scan(ScanRange::all());
    let mut s = table.scan_stream(ScanSpec::all());
    let mut got = Vec::new();
    for _ in 0..expect.len() / 3 {
        got.push(s.next_triple().unwrap());
    }
    table.minor_compact().unwrap();
    assert!(table.run_count() > 0);
    for _ in 0..expect.len() / 3 {
        got.push(s.next_triple().unwrap());
    }
    table.major_compact(&CompactionSpec::default()).unwrap();
    for tr in s {
        got.push(tr);
    }
    assert_eq!(got, expect, "mid-scan compactions changed the stream");
}

#[test]
fn combiner_at_merge_equals_combiner_at_scan() {
    // Accumulo applies combiners at compaction time as well as scan
    // time; the two must agree bit-for-bit for every RowReduce. The
    // merge path feeds the *same* ReduceIter as the scan path, so this
    // pins value formatting too (e.g. float rendering of sums).
    let reduces = [
        RowReduce::Count { out_col: "n".into() },
        RowReduce::Sum { out_col: "s".into() },
        RowReduce::Min { out_col: "lo".into() },
        RowReduce::Max { out_col: "hi".into() },
    ];
    for (i, reduce) in reduces.into_iter().enumerate() {
        let mut rng = SplitMix64::new(0x6E56 + i as u64);
        let table = random_table(&mut rng, 400);
        // Layer the input: freeze, then overwrite some cells and delete
        // a few, so the merge sees shadowed versions and tombstones.
        table.minor_compact().unwrap();
        for _ in 0..40 {
            table
                .write_batch(vec![Triple::new(
                    format!("r{:03}", rng.below(120)),
                    format!("c{:02}", rng.below(24)),
                    format!("{}", rng.range_i64(-50, 100)),
                )])
                .unwrap();
        }
        for _ in 0..20 {
            table
                .delete(&format!("r{:03}", rng.below(120)), &format!("c{:02}", rng.below(24)))
                .unwrap();
        }
        let expect = table.scan_spec(&ScanSpec::all().reduced(reduce.clone()));
        assert!(!expect.is_empty());
        table
            .major_compact(&CompactionSpec { reduce: Some(reduce.clone()), max_versions: 1 })
            .unwrap();
        // The merged run *stores* the reduced rows: a plain scan now
        // returns exactly what the scan-time combiner produced.
        let got = table.scan(ScanRange::all());
        assert_eq!(got, expect, "merge-time {reduce:?} != scan-time");
    }
}

// ---------------------------------------------------------------------
// Snapshot isolation section (PR 8)
// ---------------------------------------------------------------------
//
// Contract: `Table::scan_snapshot` pins the layer stack at open. Every
// consumption of that pin — collected at any thread count / chunk
// layout, or streamed, even partially consumed before the table moves —
// is byte-identical to the table state at pin time, no matter what
// puts, deletes, compactions, or splits land afterwards. And after the
// pin is taken, consuming it acquires **zero** tablet/table locks
// (asserted via the counting shim in `d4m::store::lock`).

#[test]
fn prop_snapshot_scan_is_isolated_from_later_mutations() {
    check("pinned snapshot scan == table state at open", 25, |g| {
        let cells = 300 + g.rng().below_usize(400);
        let table = random_table(g.rng(), cells);
        assert!(table.tablet_count() > 2, "need real tablet fan-out");
        let spec = random_spec(g.rng());
        let snap = table.scan_snapshot(&spec);
        let expect = table.scan_spec_par(&spec, Parallelism::serial());
        // Move the table hard: overwrites, deletes, fresh rows (which
        // force further splits), and both compaction flavors.
        for _ in 0..20 {
            let row = format!("r{:03}", g.rng().below(120));
            let col = format!("c{:02}", g.rng().below(24));
            table.write_batch(vec![Triple::new(row, col, "999")]).unwrap();
        }
        for _ in 0..10 {
            let row = format!("r{:03}", g.rng().below(120));
            let col = format!("c{:02}", g.rng().below(24));
            table.delete(&row, &col).unwrap();
        }
        table
            .write_batch((0..64).map(|i| Triple::new(format!("zz{i:03}"), "c", "v")).collect())
            .unwrap();
        table.minor_compact().unwrap();
        table.major_compact(&CompactionSpec::default()).unwrap();
        // The pin is oblivious: every consumption mode, every thread
        // count and chunk layout, still sees the open-time state.
        assert_eq!(expect, snap.collect(Parallelism::serial()), "serial ({spec:?})");
        for t in THREADS {
            assert_eq!(
                expect,
                snap.collect(Parallelism::with_threads(t)),
                "threads={t} ({spec:?})"
            );
        }
        let streamed: Vec<Triple> = snap.stream().collect();
        assert_eq!(expect, streamed, "streamed ({spec:?})");
        // A fresh scan sees the new state (sanity: the table did move).
        assert!(
            !table.scan(ScanRange::single("zz000")).is_empty(),
            "mutations must be visible to fresh scans"
        );
    });
}

#[test]
fn snapshot_consumption_takes_zero_locks_after_open() {
    // The tentpole assertion: opening the pin is the last lock the scan
    // ever takes. The shim counter is thread-local, so serial
    // consumption on this thread gives an exact count.
    let mut rng = SplitMix64::new(0x5EED_08);
    let table = random_table(&mut rng, 600);
    table.minor_compact().unwrap();
    assert!(table.tablet_count() > 2 && table.run_count() > 0);
    let spec = ScanSpec::ranges([
        ScanRange::rows("r000", "r040"),
        ScanRange::rows("r060", "r090").with_cols("c05", "c15"),
        ScanRange::single("r100"),
    ])
    .filtered(CellFilter::col(KeyMatch::Prefix("c".into())));
    let expect = table.scan_spec_par(&spec, Parallelism::serial());
    assert!(!expect.is_empty());
    // Pin first (locks allowed here), then count.
    let snap = table.scan_snapshot(&spec);
    let before = lock_acquisitions();
    let collected = snap.collect(Parallelism::serial());
    assert_eq!(lock_acquisitions(), before, "collect took a lock after open");
    let streamed: Vec<Triple> = snap.stream().collect();
    assert_eq!(lock_acquisitions(), before, "stream took a lock after open");
    assert_eq!(collected, expect);
    assert_eq!(streamed, expect);
    // Quiescent `scan_stream` consumption is lock-free too: the cursor
    // pins at construction and refills check only an atomic version.
    let stream = table.scan_stream(spec.clone());
    let before = lock_acquisitions();
    let via_stream: Vec<Triple> = stream.collect();
    assert_eq!(lock_acquisitions(), before, "quiescent TableStream refill took a lock");
    assert_eq!(via_stream, expect);
}

#[test]
fn partially_consumed_snapshot_stream_stays_isolated() {
    // Isolation must hold even when the table moves *between* blocks of
    // an in-flight pinned stream — including a mid-scan split of the
    // very tablet the stream is walking.
    let table = Table::new("t", TableConfig { split_threshold: 512, write_latency_us: 0 });
    table
        .write_batch((0..60).map(|i| Triple::new(format!("a{i:03}"), "c", "v")).collect())
        .unwrap();
    let spec = ScanSpec::all().batched(7);
    let snap = table.scan_snapshot(&spec);
    let expect = table.scan_spec_par(&spec, Parallelism::serial());
    let mut s = snap.stream();
    let mut got = Vec::new();
    for _ in 0..10 {
        got.push(s.next_triple().unwrap());
    }
    // Split the walked extent and shadow cells ahead of the cursor.
    table
        .write_batch((0..600).map(|i| Triple::new(format!("a{i:03}"), "c", "NEW")).collect())
        .unwrap();
    assert!(table.tablet_count() > 1, "writes must have split the tablet");
    table.delete("a030", "c").unwrap();
    table.minor_compact().unwrap();
    for tr in s {
        got.push(tr);
    }
    assert_eq!(got, expect, "in-flight pinned stream leaked post-open state");
}

// ---------------------------------------------------------------------
// Block cache section (PR 9)
// ---------------------------------------------------------------------
//
// Contract: with `DurableOptions::cache_capacity` set, run files are
// served block-by-block through a shared LRU cache. At *every*
// capacity — 0 (pin-only), smaller than one block, a few blocks, or
// unbounded — every scan flavor is byte-identical to the fully
// resident table; multi-range scans never fault the blocks between
// ranges; eviction under concurrent writers never disturbs a pinned
// scan; and the zero-locks-after-open contract extends to scans that
// fault blocks in.

/// Data-block size (in triples) the cache tests write run files with:
/// small enough that a ~1.5k-cell layered table spans dozens of blocks.
const CACHE_BLOCK_TRIPLES: usize = 64;
/// On-disk bytes of one full data block (12 bytes per triple).
const CACHE_BLOCK_BYTES: usize = CACHE_BLOCK_TRIPLES * 12;

fn cache_test_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("d4m-cache-tests")
        .join(format!("{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Build a layered durable table on disk: three write waves with minor
/// compactions between (so several runs with shadowed versions), a
/// sprinkle of deletes, and a live memtable tail carried by the WAL.
fn build_layered_dir(tag: &str) -> std::path::PathBuf {
    let dir = cache_test_dir(tag);
    let opts = DurableOptions { block_triples: CACHE_BLOCK_TRIPLES, ..Default::default() };
    let t = Table::durable_with(
        "t",
        TableConfig { split_threshold: 2048, write_latency_us: 0 },
        &dir,
        FsyncPolicy::Never,
        opts,
    )
    .unwrap();
    let mut rng = SplitMix64::new(0xCAC4E);
    for wave in 0..3u64 {
        let batch: Vec<Triple> = (0..500)
            .map(|_| {
                Triple::new(
                    format!("r{:03}", rng.below(120)),
                    format!("c{:02}", rng.below(24)),
                    format!("w{wave}-{}", rng.below(100)),
                )
            })
            .collect();
        for chunk in batch.chunks(16) {
            t.write_batch(chunk.to_vec()).unwrap();
        }
        for _ in 0..15 {
            let _ =
                t.delete(&format!("r{:03}", rng.below(120)), &format!("c{:02}", rng.below(24)));
        }
        if wave < 2 {
            t.minor_compact().unwrap();
        }
    }
    t.sync().unwrap();
    dir
}

/// The specs the capacity sweep compares: full table, a gappy
/// multi-range set, a filtered scan, and a row combiner.
fn cache_specs() -> Vec<ScanSpec> {
    vec![
        ScanSpec::all(),
        ScanSpec::ranges([
            ScanRange::rows("r000", "r010"),
            ScanRange::rows("r100", "r110").with_cols("c00", "c12"),
        ]),
        ScanSpec::all().filtered(CellFilter::col(KeyMatch::Prefix("c1".into()))),
        ScanSpec::all().reduced(RowReduce::Count { out_col: "n".into() }),
    ]
}

#[test]
fn paged_scans_bit_identical_across_cache_capacities() {
    let dir = build_layered_dir("capacities");
    // Settle once resident: the WAL tail is frozen to a run and the
    // baseline image is fixed on disk.
    let baseline: Vec<Vec<Triple>> = {
        let t = Table::recover("t", cfg_cache(), &dir, FsyncPolicy::Never).unwrap();
        assert!(t.health().cache.is_none(), "resident mode must not report cache stats");
        cache_specs().iter().map(|s| t.scan_spec_par(s, Parallelism::serial())).collect()
    };
    assert!(baseline[0].len() > 800, "need a multi-block table");
    for capacity in [0usize, CACHE_BLOCK_BYTES, 8 * CACHE_BLOCK_BYTES, usize::MAX] {
        let opts = DurableOptions::default().cache_capacity(capacity);
        let t = Table::recover_with("t", cfg_cache(), &dir, FsyncPolicy::Never, opts).unwrap();
        for (spec, expect) in cache_specs().iter().zip(&baseline) {
            assert_eq!(
                &t.scan_spec_par(spec, Parallelism::serial()),
                expect,
                "capacity={capacity} serial ({spec:?})"
            );
            for th in THREADS {
                assert_eq!(
                    &t.scan_spec_par(spec, Parallelism::with_threads(th)),
                    expect,
                    "capacity={capacity} threads={th} ({spec:?})"
                );
            }
            let streamed: Vec<Triple> = t.scan_stream(spec.clone()).collect();
            assert_eq!(&streamed, expect, "capacity={capacity} streamed ({spec:?})");
        }
        let stats = t.health().cache.expect("paged mode reports cache stats");
        assert!(stats.misses > 0, "capacity={capacity}: paged scans must fault blocks");
        if capacity < usize::MAX {
            assert!(
                stats.resident_bytes <= capacity,
                "capacity={capacity}: cache retains {} bytes",
                stats.resident_bytes
            );
        }
        if capacity == 8 * CACHE_BLOCK_BYTES {
            assert!(stats.evictions > 0, "tiny capacity must evict under a full scan");
        }
        if capacity == usize::MAX {
            assert_eq!(stats.evictions, 0, "unbounded cache must never evict");
        }
    }
}

/// Split threshold for the cache tests' recovered tables.
fn cfg_cache() -> TableConfig {
    TableConfig { split_threshold: 2048, write_latency_us: 0 }
}

#[test]
fn multi_range_paged_scans_skip_gap_blocks() {
    let dir = build_layered_dir("gaps");
    {
        let t = Table::recover("t", cfg_cache(), &dir, FsyncPolicy::Never).unwrap();
        drop(t); // settle the WAL tail into a run
    }
    // Capacity 0 retains nothing, so each scan's block faults are
    // exactly its miss delta — the per-scan faulted-blocks counter.
    let opts = DurableOptions::default().cache_capacity(0);
    let t = Table::recover_with("t", cfg_cache(), &dir, FsyncPolicy::Never, opts).unwrap();
    let full_spec = ScanSpec::all();
    let m0 = t.health().cache.unwrap().misses;
    let full = t.scan_spec_par(&full_spec, Parallelism::serial());
    let full_faults = t.health().cache.unwrap().misses - m0;
    assert!(!full.is_empty());
    // Two narrow row windows ~90 rows apart: the blocks between them
    // must never be faulted in (the index seeks straight across).
    let gap_spec = ScanSpec::ranges([
        ScanRange::rows("r000", "r008"),
        ScanRange::rows("r100", "r108"),
    ]);
    let m1 = t.health().cache.unwrap().misses;
    let gappy = t.scan_spec_par(&gap_spec, Parallelism::serial());
    let gap_faults = t.health().cache.unwrap().misses - m1;
    assert!(!gappy.is_empty());
    assert!(
        gap_faults * 2 < full_faults,
        "gap hop faulted {gap_faults} of {full_faults} blocks — index seeks must skip gaps"
    );
}

#[test]
fn mid_scan_eviction_under_concurrent_writers_stays_isolated() {
    let dir = build_layered_dir("evict-writers");
    {
        let t = Table::recover("t", cfg_cache(), &dir, FsyncPolicy::Never).unwrap();
        drop(t);
    }
    // Two blocks' worth of cache: every collect refaults and evicts.
    let opts = DurableOptions::default().cache_capacity(2 * CACHE_BLOCK_BYTES);
    let t = Table::recover_with("t", cfg_cache(), &dir, FsyncPolicy::Never, opts).unwrap();
    let spec = ScanSpec::all();
    let snap = t.scan_snapshot(&spec);
    let expect = snap.collect(Parallelism::serial());
    assert!(!expect.is_empty());
    std::thread::scope(|scope| {
        let stop = std::sync::atomic::AtomicBool::new(false);
        let stop = &stop;
        let table = &t;
        for w in 0..2usize {
            scope.spawn(move || {
                let mut wrng = SplitMix64::new(0xD00D + w as u64);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let row = format!("r{:03}", wrng.below(120));
                    let col = format!("c{:02}", wrng.below(24));
                    table.write_batch(vec![Triple::new(row, col, "w")]).unwrap();
                }
            });
        }
        for th in [1, 2, 4, 7] {
            assert_eq!(
                expect,
                snap.collect(Parallelism::with_threads(th)),
                "threads={th} under concurrent writers with eviction"
            );
        }
        let streamed: Vec<Triple> = snap.stream().collect();
        assert_eq!(expect, streamed, "streamed under concurrent writers with eviction");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
    let stats = t.health().cache.unwrap();
    assert!(stats.evictions > 0, "capped cache must have evicted under repeated scans");
    // Pinned cursors kept their blocks alive through eviction, and the
    // high-water mark stayed within capacity + pinned-per-cursor slack.
    assert!(stats.peak_live_bytes >= stats.resident_bytes);
}

#[test]
fn paged_snapshot_consumption_takes_zero_tracked_locks() {
    // PR 8's zero-locks-after-open contract must hold when the scan
    // faults blocks through the cache: block loads synchronize on the
    // cache's own (untracked) shards, never on table or tablet locks.
    let dir = build_layered_dir("lockfree");
    {
        let t = Table::recover("t", cfg_cache(), &dir, FsyncPolicy::Never).unwrap();
        drop(t);
    }
    // Capacity 0: every block read is a fresh fault, so the collect
    // below exercises the fault path, not a warm cache.
    let opts = DurableOptions::default().cache_capacity(0);
    let t = Table::recover_with("t", cfg_cache(), &dir, FsyncPolicy::Never, opts).unwrap();
    let spec = ScanSpec::all();
    let expect = t.scan_spec_par(&spec, Parallelism::serial());
    let snap = t.scan_snapshot(&spec);
    let before = lock_acquisitions();
    let collected = snap.collect(Parallelism::serial());
    assert_eq!(lock_acquisitions(), before, "cache-faulting collect took a tracked lock");
    let streamed: Vec<Triple> = snap.stream().collect();
    assert_eq!(lock_acquisitions(), before, "cache-faulting stream took a tracked lock");
    assert_eq!(collected, expect);
    assert_eq!(streamed, expect);
    let stats = t.health().cache.unwrap();
    assert!(stats.misses > 0, "the lock-free consumption must actually have faulted blocks");
}

#[test]
fn snapshot_isolated_under_concurrent_writers() {
    // Writer threads hammer the table while pinned scans are consumed
    // at several thread counts; every consumption matches the pin-time
    // state bit-for-bit.
    let mut rng = SplitMix64::new(0xBEEF_08);
    let table = random_table(&mut rng, 500);
    let spec = ScanSpec::all();
    let snap = table.scan_snapshot(&spec);
    let expect = snap.collect(Parallelism::serial());
    assert!(!expect.is_empty());
    std::thread::scope(|scope| {
        let stop = std::sync::atomic::AtomicBool::new(false);
        let stop = &stop;
        let table = &table;
        for w in 0..3usize {
            scope.spawn(move || {
                let mut wrng = SplitMix64::new(0xABC + w as u64);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let row = format!("r{:03}", wrng.below(120));
                    let col = format!("c{:02}", wrng.below(24));
                    table.write_batch(vec![Triple::new(row, col, "w")]).unwrap();
                }
            });
        }
        for t in [1, 2, 4, 7] {
            assert_eq!(
                expect,
                snap.collect(Parallelism::with_threads(t)),
                "threads={t} under concurrent writers"
            );
        }
        let streamed: Vec<Triple> = snap.stream().collect();
        assert_eq!(expect, streamed, "streamed under concurrent writers");
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
}
