//! Cross-module integration tests: workload → engines → store →
//! pipeline → graphulo → runtime, composed the way the examples and
//! benches compose them.

use d4m::assoc::{Aggregator, Assoc, ValsInput};
use d4m::baselines::{btree::BTreeEngine, hashmap::HashMapEngine, D4mEngine, Engine};
use d4m::bench::Workload;
use d4m::graphulo;
use d4m::pipeline::{IngestPipeline, PipelineConfig, ShardPolicy};
use d4m::semiring::PlusTimes;
use d4m::store::{ScanRange, TableConfig, TableStore, Triple};
use std::sync::Arc;

/// The three engines agree on every figure op at a real bench scale
/// (n=8: 2048 triples, genuine collisions), not just the prop-test
/// micro-scale.
#[test]
fn engines_agree_at_bench_scale() {
    let w = Workload::generate(8, 0xFEED);
    let d4m = D4mEngine;
    let hash = HashMapEngine;
    let btree = BTreeEngine;
    let ones = w.ones();

    let da = d4m.construct_numeric(&w.rows, &w.cols, &ones);
    let ha = hash.construct_numeric(&w.rows, &w.cols, &ones);
    let ba = btree.construct_numeric(&w.rows, &w.cols, &ones);
    let db = d4m.construct_numeric(&w.rows2, &w.cols2, &ones);
    let hb = hash.construct_numeric(&w.rows2, &w.cols2, &ones);
    let bb = btree.construct_numeric(&w.rows2, &w.cols2, &ones);
    assert_eq!(d4m.nnz(&da), hash.nnz(&ha));
    assert_eq!(d4m.nnz(&da), btree.nnz(&ba));

    let (dc, hc, bc) = (d4m.add(&da, &db), hash.add(&ha, &hb), btree.add(&ba, &bb));
    assert_eq!(d4m.nnz(&dc), hash.nnz(&hc));
    assert_eq!(d4m.checksum(&dc), btree.checksum(&bc));

    let (dm, hm, bm) = (d4m.matmul(&da, &db), hash.matmul(&ha, &hb), btree.matmul(&ba, &bb));
    assert_eq!(d4m.nnz(&dm), hash.nnz(&hm));
    assert_eq!(d4m.checksum(&dm), hash.checksum(&hm));
    assert_eq!(d4m.checksum(&dm), btree.checksum(&bm));

    let (de, he, be) =
        (d4m.elemmul(&da, &db), hash.elemmul(&ha, &hb), btree.elemmul(&ba, &bb));
    assert_eq!(d4m.nnz(&de), hash.nnz(&he));
    assert_eq!(d4m.checksum(&de), btree.checksum(&be));
}

/// Full loop: Assoc → pipeline ingest (both orientations) → tablet
/// splits → scan back → identical Assoc; Graphulo degree/TableMult
/// agree with the in-core algebra.
#[test]
fn ingest_scan_roundtrip_with_splits() {
    let w = Workload::generate(9, 0xBEEF);
    let a = Assoc::from_triples(&w.rows, &w.cols, ValsInput::NumScalar(1.0));

    let store = TableStore::new(TableConfig { split_threshold: 16 << 10, write_latency_us: 0 });
    let hits = store.create_table("t");
    let mut p = IngestPipeline::start(
        Arc::clone(&hits),
        PipelineConfig { workers: 3, policy: ShardPolicy::Hash, ..Default::default() },
    );
    for (r, c, v) in a.iter() {
        p.submit(Triple::new(r.to_string(), c.to_string(), v.to_string()));
    }
    let report = p.finish();
    assert_eq!(report.written, a.nnz());
    assert!(hits.tablet_count() > 1, "expected tablet splits at this scale");

    let back = hits.scan_to_assoc(ScanRange::all());
    assert_eq!(back, a, "pipeline+store roundtrip must be lossless");

    // Graphulo degree table == algebra count.
    let deg = store.create_table("deg");
    let nodes = graphulo::degree_table(&hits, &deg);
    assert_eq!(nodes, a.row_keys().len());
    let deg_assoc = store.read_assoc("deg").unwrap();
    let count = a.count(1);
    for (r, _, v) in deg_assoc.iter() {
        assert_eq!(count.get_num(r.clone(), 1i64), v.as_num(), "degree mismatch at {r}");
    }

    // Server-side TableMult == in-core sqin.
    let out = store.create_table("ata");
    graphulo::table_mult(&hits, &hits, &out, &PlusTimes);
    assert_eq!(store.read_assoc("ata").unwrap(), a.sqin());
}

/// TSV files written by the assoc layer ingest cleanly through the
/// store boundary and re-parse numerically.
#[test]
fn tsv_store_interop() {
    let a = Assoc::from_triples(&["r1", "r2", "r3"], &["c1", "c2", "c1"], vec![1.0, 2.5, 3.0]);
    let dir = std::env::temp_dir().join("d4m-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("interop.tsv");
    d4m::assoc::write_tsv(&a, &path).unwrap();
    let b = d4m::assoc::read_tsv(&path, Aggregator::Min).unwrap();
    assert_eq!(a, b);

    let store = TableStore::with_defaults();
    store.ingest_assoc("t", &b);
    assert_eq!(store.read_assoc("t").unwrap(), a);
    assert_eq!(store.read_assoc("t_T").unwrap(), a.transpose());
}

/// The PJRT acceleration path agrees with the host algebra on bench
/// workloads (skips when artifacts are missing).
#[test]
fn accel_path_agrees_on_workload() {
    let Ok(rt) = d4m::runtime::Runtime::load_default() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let w = Workload::generate(7, 0xACCE1);
    let a = Assoc::from_triples(&w.rows, &w.cols, ValsInput::Num(w.ones()));
    let b = Assoc::from_triples(&w.rows2, &w.cols2, ValsInput::Num(w.ones()));
    let want = a.matmul(&b);
    let (got, stats) = d4m::runtime::accel_matmul(&rt, &a, &b, &PlusTimes).unwrap();
    assert_eq!(got, want);
    assert!(stats.kernel_calls > 0);
}

/// String algebra composes across the whole stack: string construct →
/// store roundtrip → mask → combine.
#[test]
fn string_pipeline_end_to_end() {
    let w = Workload::generate(6, 0x57);
    let a = Assoc::try_new(
        w.rows.iter().map(|s| s.as_str().into()).collect(),
        w.cols.iter().map(|s| s.as_str().into()).collect(),
        ValsInput::Str(w.str_vals.clone()),
        Aggregator::Min,
    )
    .unwrap();
    assert!(a.is_string());

    let store = TableStore::with_defaults();
    store.ingest_assoc("s", &a);
    let back = store.read_assoc("s").unwrap();
    assert_eq!(back, a);

    // Mask by the numeric logical of itself: identity.
    let masked = back.elemmul(&a.logical());
    assert_eq!(masked, a);

    // combine with itself under Min: also identity.
    let combined = a.combine_strings(&a, Aggregator::Min);
    assert_eq!(combined, a);
}
