//! Determinism-first equivalence suite for the parallel compute core.
//!
//! Contract under test: for every figure operation (constructor with
//! numeric and string values, `+`, `*`, `@`) and every builtin
//! semiring, the parallel result at `threads ∈ {2, 4, 7}` is
//! **byte-identical** to the `threads == 1` (exact serial code path)
//! result — same keys, same value pool, same adj triples bit-for-bit,
//! same checksum. Checked at bench scale (`Workload::generate`) and on
//! adversarial shapes (empty, 1×n, n×1, all-collisions), plus the
//! parallel tablet scan against the serial scan.
//!
//! The SpGEMM section extends the contract across the adaptive
//! engine's accumulator policies: on hypersparse (1 nnz/row),
//! power-law-row, and empty-row-band shapes, every forced policy
//! (dense / sort / hash) must agree bit-for-bit with each other, with
//! the adaptive selection, and with the serial path, for every builtin
//! semiring and thread count.

use d4m::assoc::{Aggregator, Assoc, Key, ValsInput};
use d4m::bench::Workload;
use d4m::semiring::{MaxMin, MaxPlus, MinPlus, PlusTimes, Semiring};
use d4m::sparse::{
    spgemm_masked_with_stats_par, spgemm_with_policy_par, AccumulatorPolicy, CooMatrix, CsrMatrix,
};
use d4m::store::{ScanRange, Table, TableConfig, Triple};
use d4m::util::{Parallelism, SplitMix64};

/// Thread counts exercised against the serial baseline. 7 is
/// deliberately not a power of two (uneven chunk boundaries).
const THREADS: [usize; 3] = [2, 4, 7];

fn builtin_semirings() -> Vec<Box<dyn Semiring>> {
    vec![Box::new(PlusTimes), Box::new(MaxPlus), Box::new(MinPlus), Box::new(MaxMin)]
}

fn keys(ss: &[String]) -> Vec<Key> {
    ss.iter().map(|s| Key::str(s.as_str())).collect()
}

/// Byte-level fingerprint of an `Assoc`: every attribute the paper
/// stores, with values taken as raw bits (so `-0.0` vs `0.0` or NaN
/// payload drift would be caught, unlike `f64` equality).
#[derive(Debug, PartialEq, Eq)]
struct Fingerprint {
    rows: Vec<String>,
    cols: Vec<String>,
    numeric: bool,
    pool: Vec<String>,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    value_bits: Vec<u64>,
    checksum: u64,
}

fn fingerprint(a: &Assoc) -> Fingerprint {
    let rows: Vec<String> = a.row_keys().iter().map(|k| k.to_string()).collect();
    let cols: Vec<String> = a.col_keys().iter().map(|k| k.to_string()).collect();
    let pool: Vec<String> = a
        .values()
        .strings()
        .map(|p| p.iter().map(|s| s.to_string()).collect())
        .unwrap_or_default();
    let indptr = a.adj().indptr().to_vec();
    let indices = a.adj().indices().to_vec();
    let value_bits: Vec<u64> = a.adj().values().iter().map(|v| v.to_bits()).collect();

    // FNV-1a over the serialized attributes.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for s in rows.iter().chain(&cols).chain(&pool) {
        eat(s.as_bytes());
        eat(&[0xff]);
    }
    for &p in &indptr {
        eat(&(p as u64).to_le_bytes());
    }
    for &c in &indices {
        eat(&c.to_le_bytes());
    }
    for &v in &value_bits {
        eat(&v.to_le_bytes());
    }
    Fingerprint {
        rows,
        cols,
        numeric: a.is_numeric(),
        pool,
        indptr,
        indices,
        value_bits,
        checksum: h,
    }
}

/// Assert byte-identity (readable structural diff first, then the
/// bit-exact fingerprint including the checksum).
fn assert_identical(serial: &Assoc, parallel: &Assoc, ctx: &str) {
    assert_eq!(serial, parallel, "{ctx}: structural mismatch");
    assert_eq!(fingerprint(serial), fingerprint(parallel), "{ctx}: fingerprint mismatch");
}

// ---------------------------------------------------------------------
// Constructor (Figures 3–4)
// ---------------------------------------------------------------------

#[test]
fn construct_numeric_equivalence_bench_scale() {
    let w = Workload::generate(8, 0xC0FF_EE01);
    for agg in [
        Aggregator::Min,
        Aggregator::Max,
        Aggregator::Sum,
        Aggregator::Prod,
        Aggregator::First,
        Aggregator::Last,
    ] {
        let serial = Assoc::try_new_par(
            keys(&w.rows),
            keys(&w.cols),
            ValsInput::Num(w.num_vals.clone()),
            agg.clone(),
            Parallelism::serial(),
        )
        .unwrap();
        for t in THREADS {
            let par = Assoc::try_new_par(
                keys(&w.rows),
                keys(&w.cols),
                ValsInput::Num(w.num_vals.clone()),
                agg.clone(),
                Parallelism::with_threads(t),
            )
            .unwrap();
            assert_identical(&serial, &par, &format!("construct numeric {agg:?} t={t}"));
        }
    }
}

#[test]
fn construct_string_equivalence_bench_scale() {
    let w = Workload::generate(8, 0xC0FF_EE02);
    for agg in [
        Aggregator::Min,
        Aggregator::Max,
        Aggregator::First,
        Aggregator::Last,
        Aggregator::Concat(";".into()),
    ] {
        let serial = Assoc::try_new_par(
            keys(&w.rows),
            keys(&w.cols),
            ValsInput::Str(w.str_vals.clone()),
            agg.clone(),
            Parallelism::serial(),
        )
        .unwrap();
        for t in THREADS {
            let par = Assoc::try_new_par(
                keys(&w.rows),
                keys(&w.cols),
                ValsInput::Str(w.str_vals.clone()),
                agg.clone(),
                Parallelism::with_threads(t),
            )
            .unwrap();
            assert_identical(&serial, &par, &format!("construct string {agg:?} t={t}"));
        }
    }
}

// ---------------------------------------------------------------------
// Binary figure ops (Figures 5–7) over every builtin semiring
// ---------------------------------------------------------------------

/// Bench-scale numeric operands (n = 10 is the acceptance workload;
/// large enough that every parallel gate actually fans out).
fn bench_operands() -> (Assoc, Assoc) {
    let w = Workload::generate(10, 0xD4A7_0001);
    let a = Assoc::try_new_par(
        keys(&w.rows),
        keys(&w.cols),
        ValsInput::Num(w.num_vals.clone()),
        Aggregator::Min,
        Parallelism::serial(),
    )
    .unwrap();
    let b = Assoc::try_new_par(
        keys(&w.rows2),
        keys(&w.cols2),
        ValsInput::Num(w.num_vals.clone()),
        Aggregator::Min,
        Parallelism::serial(),
    )
    .unwrap();
    (a, b)
}

#[test]
fn add_equivalence_all_semirings() {
    let (a, b) = bench_operands();
    for s in builtin_semirings() {
        let serial = a.add_with_par(&b, s.as_ref(), Parallelism::serial());
        for t in THREADS {
            let par = a.add_with_par(&b, s.as_ref(), Parallelism::with_threads(t));
            assert_identical(&serial, &par, &format!("add {} t={t}", s.name()));
        }
    }
}

#[test]
fn elemmul_equivalence_all_semirings() {
    let (a, b) = bench_operands();
    for s in builtin_semirings() {
        let serial = a.elemmul_with_par(&b, s.as_ref(), Parallelism::serial());
        for t in THREADS {
            let par = a.elemmul_with_par(&b, s.as_ref(), Parallelism::with_threads(t));
            assert_identical(&serial, &par, &format!("elemmul {} t={t}", s.name()));
        }
    }
}

#[test]
fn matmul_equivalence_all_semirings() {
    let (a, b) = bench_operands();
    for s in builtin_semirings() {
        let serial = a.matmul_with_par(&b, s.as_ref(), Parallelism::serial());
        assert!(!serial.is_empty(), "matmul workload must produce output");
        for t in THREADS {
            let par = a.matmul_with_par(&b, s.as_ref(), Parallelism::with_threads(t));
            assert_identical(&serial, &par, &format!("matmul {} t={t}", s.name()));
        }
    }
}

#[test]
fn string_ops_equivalence() {
    // String `+` (concat combine), string `*` (lex min), string × mask.
    let w = Workload::generate(8, 0xD4A7_0002);
    let mk = |rows: &[String], cols: &[String], par: Parallelism| {
        Assoc::try_new_par(
            keys(rows),
            keys(cols),
            ValsInput::Str(w.str_vals.clone()),
            Aggregator::Min,
            par,
        )
        .unwrap()
    };
    let a = mk(&w.rows, &w.cols, Parallelism::serial());
    let b = mk(&w.rows2, &w.cols2, Parallelism::serial());
    let mask = Assoc::try_new_par(
        keys(&w.rows2),
        keys(&w.cols2),
        ValsInput::NumScalar(1.0),
        Aggregator::Min,
        Parallelism::serial(),
    )
    .unwrap();
    let add1 = a.add_par(&b, Parallelism::serial());
    let mul1 = a.elemmul_par(&b, Parallelism::serial());
    let msk1 = a.elemmul_par(&mask, Parallelism::serial());
    for t in THREADS {
        let par = Parallelism::with_threads(t);
        assert_identical(&add1, &a.add_par(&b, par), &format!("string add t={t}"));
        assert_identical(&mul1, &a.elemmul_par(&b, par), &format!("string elemmul t={t}"));
        assert_identical(&msk1, &a.elemmul_par(&mask, par), &format!("string mask t={t}"));
    }
}

// ---------------------------------------------------------------------
// Adversarial shapes
// ---------------------------------------------------------------------

#[test]
fn adversarial_empty_operands() {
    let e = Assoc::empty();
    let (a, _) = bench_operands();
    for t in THREADS {
        let par = Parallelism::with_threads(t);
        assert_identical(&e.matmul_par(&e, Parallelism::serial()), &e.matmul_par(&e, par), "∅@∅");
        assert_identical(&a.add_par(&e, Parallelism::serial()), &a.add_par(&e, par), "A+∅");
        assert_identical(
            &a.elemmul_par(&e, Parallelism::serial()),
            &a.elemmul_par(&e, par),
            "A*∅",
        );
        // Empty constructor inputs.
        let c = Assoc::try_new_par(
            Vec::new(),
            Vec::new(),
            ValsInput::Num(Vec::new()),
            Aggregator::Min,
            par,
        )
        .unwrap();
        assert_identical(&e, &c, "empty constructor");
    }
}

#[test]
fn adversarial_single_row_and_single_column() {
    // Big enough to clear every parallel gate, small enough that the
    // n×n outer product below stays cheap.
    let n = 600usize;
    let wide_cols: Vec<String> = (0..n).map(|i| format!("c{i:05}")).collect();
    let one_row: Vec<String> = vec!["r".to_string(); n];
    let vals: Vec<f64> = (0..n).map(|i| (i % 97 + 1) as f64).collect();

    // 1×n and n×1 constructors.
    let mk = |rows: &[String], cols: &[String], par: Parallelism| {
        Assoc::try_new_par(
            keys(rows),
            keys(cols),
            ValsInput::Num(vals.clone()),
            Aggregator::Sum,
            par,
        )
        .unwrap()
    };
    let wide1 = mk(&one_row, &wide_cols, Parallelism::serial());
    let tall1 = mk(&wide_cols, &one_row, Parallelism::serial());
    assert_eq!(wide1.shape(), (1, n));
    assert_eq!(tall1.shape(), (n, 1));
    // (1×n) @ (n×1) → 1×1 and (n×1) @ (1×n) → n×n contraction shapes.
    let dot1 = wide1.matmul_par(&tall1, Parallelism::serial());
    let outer1 = tall1.matmul_par(&wide1, Parallelism::serial());
    for t in THREADS {
        let par = Parallelism::with_threads(t);
        assert_identical(&wide1, &mk(&one_row, &wide_cols, par), &format!("1×n ctor t={t}"));
        assert_identical(&tall1, &mk(&wide_cols, &one_row, par), &format!("n×1 ctor t={t}"));
        assert_identical(&dot1, &wide1.matmul_par(&tall1, par), &format!("1×n @ n×1 t={t}"));
        assert_identical(&outer1, &tall1.matmul_par(&wide1, par), &format!("n×1 @ 1×n t={t}"));
    }
}

#[test]
fn adversarial_all_collisions() {
    // Every triple lands on the same (row, col) cell.
    let n = 2000usize;
    let rows: Vec<String> = vec!["r".to_string(); n];
    let cols: Vec<String> = vec!["c".to_string(); n];
    let vals: Vec<f64> = (0..n).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
    for agg in [Aggregator::Min, Aggregator::Max, Aggregator::Sum, Aggregator::Last] {
        let serial = Assoc::try_new_par(
            keys(&rows),
            keys(&cols),
            ValsInput::Num(vals.clone()),
            agg.clone(),
            Parallelism::serial(),
        )
        .unwrap();
        for t in THREADS {
            let par = Assoc::try_new_par(
                keys(&rows),
                keys(&cols),
                ValsInput::Num(vals.clone()),
                agg.clone(),
                Parallelism::with_threads(t),
            )
            .unwrap();
            assert_identical(&serial, &par, &format!("all-collisions {agg:?} t={t}"));
        }
    }
    // String flavour: identical keys, colliding string values.
    let svals: Vec<String> = (0..n).map(|i| format!("v{:03}", i % 50)).collect();
    let serial = Assoc::try_new_par(
        keys(&rows),
        keys(&cols),
        ValsInput::Str(svals.clone()),
        Aggregator::Min,
        Parallelism::serial(),
    )
    .unwrap();
    for t in THREADS {
        let par = Assoc::try_new_par(
            keys(&rows),
            keys(&cols),
            ValsInput::Str(svals.clone()),
            Aggregator::Min,
            Parallelism::with_threads(t),
        )
        .unwrap();
        assert_identical(&serial, &par, &format!("all-collisions string t={t}"));
    }
}

// ---------------------------------------------------------------------
// SpGEMM accumulator policies on hypersparse / skewed shapes
// ---------------------------------------------------------------------

/// Structural + raw-bit CSR equality (catches `-0.0` vs `0.0` and NaN
/// payload drift that `f64` equality would hide).
fn assert_csr_bits(x: &CsrMatrix, y: &CsrMatrix, ctx: &str) {
    assert_eq!(x.shape(), y.shape(), "{ctx}: shape");
    assert_eq!(x.indptr(), y.indptr(), "{ctx}: indptr");
    assert_eq!(x.indices(), y.indices(), "{ctx}: indices");
    let xb: Vec<u64> = x.values().iter().map(|v| v.to_bits()).collect();
    let yb: Vec<u64> = y.values().iter().map(|v| v.to_bits()).collect();
    assert_eq!(xb, yb, "{ctx}: value bits");
}

fn csr_from(n: usize, t: &[(usize, usize, f64)]) -> CsrMatrix {
    let rows: Vec<usize> = t.iter().map(|x| x.0).collect();
    let cols: Vec<usize> = t.iter().map(|x| x.1).collect();
    let vals: Vec<f64> = t.iter().map(|x| x.2).collect();
    CooMatrix::from_triples_aggregate(n, n, &rows, &cols, &vals, 0.0, f64::min)
        .unwrap()
        .to_csr()
}

/// Exactly one stored entry per row — the hypersparse extreme (the
/// adaptive engine's copy path on every row).
fn one_nnz_per_row(n: usize, seed: u64) -> CsrMatrix {
    let mut r = SplitMix64::new(seed);
    let t: Vec<(usize, usize, f64)> =
        (0..n).map(|i| (i, r.below_usize(n), (i % 7 + 1) as f64)).collect();
    csr_from(n, &t)
}

/// Power-law row sizes: a few very dense rows, a long 1-entry tail —
/// one matrix that exercises the dense, hash, sort, and copy paths.
fn power_law_rows(n: usize, seed: u64) -> CsrMatrix {
    let mut r = SplitMix64::new(seed);
    let mut t = Vec::new();
    for i in 0..n {
        // Row degree halves every few rows: n/2, then /4, … down to 1.
        let deg = (n >> (1 + i / 3).min(usize::BITS as usize - 1)).max(1);
        for _ in 0..deg {
            t.push((i, r.below_usize(n), (i % 5 + 1) as f64));
        }
    }
    csr_from(n, &t)
}

/// A contiguous band of entirely empty rows between two sparse bands
/// (empty rows must emit nothing and cost nothing, at any chunking).
fn empty_row_band(n: usize, seed: u64) -> CsrMatrix {
    let mut r = SplitMix64::new(seed);
    let mut t = Vec::new();
    for i in 0..n {
        if i >= n / 4 && i < 3 * n / 4 {
            continue;
        }
        for _ in 0..3 {
            t.push((i, r.below_usize(n), (i % 11 + 1) as f64));
        }
    }
    csr_from(n, &t)
}

#[test]
fn spgemm_policies_agree_on_adversarial_shapes() {
    let n = 300usize;
    let shapes: Vec<(&str, CsrMatrix, CsrMatrix)> = vec![
        ("hypersparse @ hypersparse", one_nnz_per_row(n, 1), one_nnz_per_row(n, 2)),
        ("power-law @ power-law", power_law_rows(n, 3), power_law_rows(n, 4)),
        ("empty-band @ empty-band", empty_row_band(n, 5), empty_row_band(n, 6)),
        ("power-law @ hypersparse", power_law_rows(n, 7), one_nnz_per_row(n, 8)),
        ("hypersparse @ empty-band", one_nnz_per_row(n, 9), empty_row_band(n, 10)),
    ];
    for (name, a, b) in &shapes {
        for s in builtin_semirings() {
            let (base, base_stats) = spgemm_with_policy_par(
                a,
                b,
                s.as_ref(),
                Parallelism::serial(),
                AccumulatorPolicy::Adaptive,
            )
            .unwrap();
            for policy in [
                AccumulatorPolicy::Adaptive,
                AccumulatorPolicy::Dense,
                AccumulatorPolicy::Sort,
                AccumulatorPolicy::Hash,
            ] {
                for t in [1usize, 2, 4, 7] {
                    let (c, stats) = spgemm_with_policy_par(
                        a,
                        b,
                        s.as_ref(),
                        Parallelism::with_threads(t),
                        policy,
                    )
                    .unwrap();
                    let ctx = format!("{name} {} {policy:?} t={t}", s.name());
                    assert_csr_bits(&base, &c, &ctx);
                    assert_eq!(base_stats.mults, stats.mults, "{ctx}: flop count");
                    assert_eq!(base_stats.out_nnz, stats.out_nnz, "{ctx}: out nnz");
                }
            }
        }
    }
}

#[test]
fn spgemm_adaptive_uses_expected_paths() {
    // The hypersparse shape must ride the copy path; the power-law
    // shape must spread across at least three accumulators — guards
    // against the policy heuristic silently collapsing to one kernel.
    let n = 300usize;
    let hyper = one_nnz_per_row(n, 21);
    let (_, st) = spgemm_with_policy_par(
        &hyper,
        &hyper,
        &PlusTimes,
        Parallelism::serial(),
        AccumulatorPolicy::Adaptive,
    )
    .unwrap();
    assert_eq!(st.rows_sort + st.rows_hash + st.rows_dense, 0, "hypersparse is all copy rows");
    assert!(st.rows_copy > 0);

    let pow = power_law_rows(n, 22);
    let (_, st) = spgemm_with_policy_par(
        &pow,
        &pow,
        &PlusTimes,
        Parallelism::serial(),
        AccumulatorPolicy::Adaptive,
    )
    .unwrap();
    let kinds = [st.rows_copy, st.rows_sort, st.rows_hash, st.rows_dense];
    let used = kinds.iter().filter(|&&k| k > 0).count();
    assert!(used >= 3, "power-law rows should mix accumulators, got {kinds:?}");
}

// ---------------------------------------------------------------------
// Masked SpGEMM
// ---------------------------------------------------------------------

/// Expected masked result: the unmasked product with mask-false columns
/// dropped, as raw arrays (value bits, so the comparison is bit-exact).
fn drop_cols_arrays(c: &CsrMatrix, mask: &[bool]) -> (Vec<usize>, Vec<u32>, Vec<u64>) {
    let mut indptr = vec![0usize];
    let mut idx: Vec<u32> = Vec::new();
    let mut bits: Vec<u64> = Vec::new();
    for r in 0..c.shape().0 {
        let (ci, cv) = c.row(r);
        for (col, v) in ci.iter().zip(cv) {
            if mask[*col as usize] {
                idx.push(*col);
                bits.push(v.to_bits());
            }
        }
        indptr.push(idx.len());
    }
    (indptr, idx, bits)
}

#[test]
fn masked_spgemm_equals_unmasked_then_mask() {
    // The PR 3 contract: for every adversarial shape, builtin semiring,
    // mask density, and thread count, the masked multiply is
    // bit-identical to the unmasked product with the masked-out columns
    // dropped — and never does more flops than the unmasked run.
    let n = 300usize;
    let shapes: Vec<(&str, CsrMatrix, CsrMatrix)> = vec![
        ("hypersparse @ hypersparse", one_nnz_per_row(n, 31), one_nnz_per_row(n, 32)),
        ("power-law @ power-law", power_law_rows(n, 33), power_law_rows(n, 34)),
        ("power-law @ empty-band", power_law_rows(n, 35), empty_row_band(n, 36)),
    ];
    let mut rng = SplitMix64::new(0x3A5C_ED);
    let densities = [0.0f64, 0.1, 0.5, 1.0];
    for (name, a, b) in &shapes {
        for &density in &densities {
            let mask: Vec<bool> = (0..n)
                .map(|_| match density {
                    d if d <= 0.0 => false,
                    d if d >= 1.0 => true,
                    d => rng.chance(d),
                })
                .collect();
            for s in builtin_semirings() {
                let (full, full_stats) = spgemm_with_policy_par(
                    a,
                    b,
                    s.as_ref(),
                    Parallelism::serial(),
                    AccumulatorPolicy::Adaptive,
                )
                .unwrap();
                let (ptr, idx, bits) = drop_cols_arrays(&full, &mask);
                for t in [1usize, 2, 4, 7] {
                    let (got, stats) = spgemm_masked_with_stats_par(
                        a,
                        b,
                        s.as_ref(),
                        Parallelism::with_threads(t),
                        &mask,
                    )
                    .unwrap();
                    let ctx = format!("{name} {} density={density} t={t}", s.name());
                    assert_eq!(got.shape(), full.shape(), "{ctx}: shape");
                    assert_eq!(got.indptr(), &ptr[..], "{ctx}: indptr");
                    assert_eq!(got.indices(), &idx[..], "{ctx}: indices");
                    let gbits: Vec<u64> = got.values().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gbits, bits, "{ctx}: value bits");
                    assert!(
                        stats.mults <= full_stats.mults,
                        "{ctx}: masked flops {} exceed unmasked {}",
                        stats.mults,
                        full_stats.mults
                    );
                    if density <= 0.0 {
                        assert_eq!(stats.mults, 0, "{ctx}: empty mask must cost zero flops");
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Parallel tablet scan
// ---------------------------------------------------------------------

#[test]
fn table_scan_equivalence_across_tablets() {
    // Small split threshold → many tablets, so the scan really fans
    // out. Splits happen at most once per write_batch call, so write
    // many small batches.
    let table = Table::new("t", TableConfig { split_threshold: 512, write_latency_us: 0 });
    let triples: Vec<Triple> = (0..2000)
        .map(|i| Triple::new(format!("row{i:05}"), format!("c{}", i % 7), format!("v{i}")))
        .collect();
    for chunk in triples.chunks(10) {
        table.write_batch(chunk.to_vec()).unwrap();
    }
    assert!(table.tablet_count() > 4, "expected many tablets, got {}", table.tablet_count());

    let full1 = table.scan_par(ScanRange::all(), Parallelism::serial());
    assert_eq!(full1.len(), 2000);
    let ranged = ScanRange::rows("row00500", "row01500");
    let ranged1 = table.scan_par(ranged.clone(), Parallelism::serial());
    assert_eq!(ranged1.len(), 1000);
    let assoc1 = table.scan_to_assoc_par(ScanRange::all(), Parallelism::serial());
    for t in THREADS {
        let par = Parallelism::with_threads(t);
        assert_eq!(full1, table.scan_par(ScanRange::all(), par), "full scan t={t}");
        assert_eq!(ranged1, table.scan_par(ranged.clone(), par), "ranged scan t={t}");
        assert_identical(
            &assoc1,
            &table.scan_to_assoc_par(ScanRange::all(), par),
            &format!("scan_to_assoc t={t}"),
        );
    }
}
