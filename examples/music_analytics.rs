//! Facet search and graph analytics over a synthetic music catalog —
//! the workload class the paper's introduction motivates (spreadsheets
//! / databases / graphs under one algebra).
//!
//! Builds a ~30k-entry string associative array of track metadata,
//! then answers analyst questions purely with the D4M algebra:
//! facet counts, co-occurrence graphs (`AᵀA`), artist similarity, and
//! semiring-powered widest-path queries over the collaboration graph.
//!
//! Run: `cargo run --release --example music_analytics`

use d4m::assoc::{Aggregator, Assoc, Selector, ValsInput};
use d4m::semiring::MaxMin;
use d4m::util::{human, SplitMix64, Stopwatch};

const GENRES: [&str; 8] =
    ["rock", "pop", "jazz", "classical", "electronic", "folk", "hiphop", "ambient"];
const LABELS: [&str; 6] = ["EMI", "Sub Pop", "Blue Note", "DG", "Warp", "Merge"];

fn main() {
    let mut rng = SplitMix64::new(0xD4A7);
    let n_tracks = 10_000usize;
    let n_artists = 400usize;

    // --- build the catalog as one exploded string array ----------------
    let sw = Stopwatch::start();
    let mut rows: Vec<String> = Vec::new();
    let mut cols: Vec<String> = Vec::new();
    let mut vals: Vec<String> = Vec::new();
    for t in 0..n_tracks {
        let track = format!("{t:06}.mp3");
        let artist = format!("artist{:03}", rng.below(n_artists as u64));
        let push = |rows: &mut Vec<String>, cols: &mut Vec<String>, vals: &mut Vec<String>,
                    c: &str, v: String| {
            rows.push(track.clone());
            cols.push(c.to_string());
            vals.push(v);
        };
        push(&mut rows, &mut cols, &mut vals, "artist", artist);
        push(&mut rows, &mut cols, &mut vals, "genre", rng.choose(&GENRES).to_string());
        push(&mut rows, &mut cols, &mut vals, "label", rng.choose(&LABELS).to_string());
        push(
            &mut rows,
            &mut cols,
            &mut vals,
            "duration",
            format!("{}:{:02}", 2 + rng.below(7), rng.below(60)),
        );
    }
    let a = Assoc::try_new(
        rows.iter().map(|s| s.as_str().into()).collect(),
        cols.iter().map(|s| s.as_str().into()).collect(),
        ValsInput::Str(vals),
        Aggregator::Min,
    )
    .unwrap();
    println!(
        "catalog: {} ({} tracks × {} fields) built in {}",
        a.summary(),
        n_tracks,
        a.col_keys().len(),
        human::seconds(sw.elapsed_s())
    );

    // --- facet search: D4M's "exploded schema" idiom --------------------
    // Explode values into columns: E[track, "genre|rock"] = 1.
    let (tr, tc, tv) = a.triples();
    let exploded_cols: Vec<String> = match &tv {
        ValsInput::Str(vs) => tc
            .iter()
            .zip(vs)
            .map(|(c, v)| format!("{c}|{v}"))
            .collect(),
        _ => unreachable!(),
    };
    let e = Assoc::try_new(
        tr,
        exploded_cols.iter().map(|s| s.as_str().into()).collect(),
        ValsInput::NumScalar(1.0),
        Aggregator::Min,
    )
    .unwrap();
    println!("exploded: {}", e.summary());

    // Facet counts per genre: one column-sum over the exploded array.
    let facet = e
        .select(&Selector::All, &Selector::Prefix("genre|".into()))
        .sum(0);
    println!("\ngenre facet counts:\n{facet}");

    // Tracks that are rock AND on EMI: filter the EMI indicator column
    // down to the rock tracks' row keys (the D4M join idiom — an
    // elementwise multiply would intersect *column* keys, which differ).
    let rock = e.get_col("genre|rock");
    let emi = e.get_col("label|EMI");
    let both = emi.select(&Selector::Keys(rock.row_keys().to_vec()), &Selector::All);
    println!("rock ∧ EMI tracks: {}", both.nnz());

    // --- graph analytics: AᵀA on the exploded array ----------------------
    let sw = Stopwatch::start();
    let ata = e.sqin();
    println!(
        "\nAᵀA co-occurrence graph: {} in {}",
        ata.summary(),
        human::seconds(sw.elapsed_s())
    );
    // Strongest genre↔label affinity.
    let genre_label = ata.select(
        &Selector::Prefix("genre|".into()),
        &Selector::Prefix("label|".into()),
    );
    let mut best = ("", "", 0.0);
    for (r, c, v) in genre_label.iter() {
        let v = v.as_num().unwrap();
        if v > best.2 {
            best = (
                r.as_str().unwrap_or_default(),
                c.as_str().unwrap_or_default(),
                v,
            );
        }
    }
    println!("strongest genre↔label pair: {} × {} ({} tracks)", best.0, best.1, best.2);

    // --- semiring query: widest path in the artist collaboration graph --
    // Artist similarity = number of shared (genre, label) facets.
    let by_artist = {
        // P[artist, facet] = count of artist's tracks with that facet.
        let artist_col = a.get_col("artist");
        let (ar, _, av) = artist_col.triples();
        let artists: Vec<String> = match av {
            ValsInput::Str(vs) => vs,
            _ => unreachable!(),
        };
        // Map track -> artist, then group exploded facets by artist.
        let track_to_artist: std::collections::HashMap<String, String> = ar
            .iter()
            .map(|k| k.to_string())
            .zip(artists)
            .collect();
        let mut prows = Vec::new();
        let mut pcols = Vec::new();
        for (t, c, _) in e.iter() {
            if let Some(artist) = track_to_artist.get(&t.to_string()) {
                if !c.to_string().starts_with("duration|") {
                    prows.push(artist.clone());
                    pcols.push(c.to_string());
                }
            }
        }
        Assoc::try_new(
            prows.iter().map(|s| s.as_str().into()).collect(),
            pcols.iter().map(|s| s.as_str().into()).collect(),
            ValsInput::NumScalar(1.0),
            Aggregator::Sum,
        )
        .unwrap()
    };
    let sim = by_artist.sqout(); // artist × artist shared-facet counts
    println!("\nartist similarity graph: {}", sim.summary());

    // Widest path (max-min semiring) between two artists through one
    // intermediate: similarity "bandwidth" of the best 2-hop connection.
    let sw = Stopwatch::start();
    let two_hop = sim.matmul_with(&sim, &MaxMin);
    println!(
        "max-min 2-hop similarity: {} in {}",
        two_hop.summary(),
        human::seconds(sw.elapsed_s())
    );
    let (a0, a1) = ("artist000", "artist001");
    println!(
        "widest 2-hop connection {a0} → {a1}: {:?} (direct: {:?})",
        two_hop.get_num(a0, a1),
        sim.get_num(a0, a1)
    );
    println!("\nmusic_analytics OK");
}
