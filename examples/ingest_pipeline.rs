//! END-TO-END DRIVER: the full D4M stack on a realistic workload.
//!
//! Exercises every layer in one run (recorded in EXPERIMENTS.md):
//!
//! 1. **Generate** a synthetic web-log corpus (~200k triples: client →
//!    url hits with bytes + status fields), the kind of semi-structured
//!    data D4M's ingest deployments handle.
//! 2. **Ingest** it through the sharded, backpressured pipeline into
//!    the Accumulo-sim table store (adjacency + transpose tables),
//!    reporting throughput, stalls, shard balance and tablet splits.
//! 3. **Query** with Graphulo server-side kernels (degree tables,
//!    one-scan-per-hop BFS, seeded jaccard), the server-side iterator
//!    stack (filtered streaming scans, multi-range BatchScanner-style
//!    scans, combiner pushdown, masked TableMult), and scan-to-Assoc +
//!    the associative-array algebra (facets, AᵀA).
//! 4. **Accelerate**: run the correlation matmul on the PJRT dense-
//!    block path (AOT Pallas kernel) and cross-check it against host
//!    SpGEMM — proving artifacts, runtime and algebra compose.
//! 5. **Report** the paper's five op timings (Figs 3–7 ops) on the
//!    ingested real data.
//!
//! Run: `cargo run --release --example ingest_pipeline`

use d4m::assoc::{Aggregator, Assoc, ValsInput};
use d4m::bench::Workload;
use d4m::graphulo;
use d4m::pipeline::{IngestPipeline, PipelineConfig, ShardPolicy};
use d4m::semiring::PlusTimes;
use d4m::store::{
    CellFilter, KeyMatch, RowReduce, ScanRange, ScanSpec, TableConfig, TableStore, Triple,
};
use d4m::util::{human, time_op, SplitMix64, Stopwatch};
use std::sync::Arc;

const N_EVENTS: usize = 100_000;
const N_CLIENTS: u64 = 5_000;
const N_URLS: u64 = 800;

fn main() {
    println!("== D4M end-to-end driver ==\n");

    // ---- 1. generate the corpus ---------------------------------------
    let mut rng = SplitMix64::new(0x1091);
    let mut events: Vec<(String, String, String, String)> = Vec::with_capacity(N_EVENTS);
    for _ in 0..N_EVENTS {
        // Zipf-ish skew: square the uniform to concentrate mass.
        let c = ((rng.f64() * rng.f64()) * N_CLIENTS as f64) as u64;
        let u = ((rng.f64() * rng.f64()) * N_URLS as f64) as u64;
        let status = *rng.choose(&["200", "200", "200", "304", "404", "500"]);
        let bytes = (rng.below(64) + 1) * 512;
        events.push((
            format!("client{c:05}"),
            format!("/page{u:04}"),
            status.to_string(),
            bytes.to_string(),
        ));
    }
    println!("corpus: {} web-log events", human::count(N_EVENTS as u64));

    // ---- 2. pipeline ingest into the store ------------------------------
    let store = TableStore::new(TableConfig { split_threshold: 1 << 20, write_latency_us: 0 });
    let hits = store.create_table("hits");
    let hits_t = store.create_table("hits_T");

    let mut p = IngestPipeline::start(
        Arc::clone(&hits),
        PipelineConfig { workers: 4, policy: ShardPolicy::Hash, ..Default::default() },
    );
    let mut pt = IngestPipeline::start(Arc::clone(&hits_t), PipelineConfig::default());
    let sw = Stopwatch::start();
    for (client, url, _, _) in &events {
        p.submit(Triple::new(client.clone(), url.clone(), "1"));
        pt.submit(Triple::new(url.clone(), client.clone(), "1"));
    }
    let report = p.finish();
    let report_t = pt.finish();
    println!(
        "ingest: {} triples in {} → {} (x2 for transpose table), \
         {} stalls, imbalance {:.2}, {} tablets",
        human::count((report.written + report_t.written) as u64),
        human::seconds(sw.elapsed_s()),
        human::rate(report.rate()),
        report.stalls,
        report.imbalance(),
        hits.tablet_count(),
    );

    // ---- 3. server-side analytics (Graphulo) ----------------------------
    let deg_out = store.create_table("deg_client");
    let deg_in = store.create_table("deg_url");
    let sw = Stopwatch::start();
    let clients = graphulo::degree_table(&hits, &deg_out);
    let urls = graphulo::degree_table(&hits_t, &deg_in);
    println!(
        "\ndegree tables: {clients} clients, {urls} urls in {}",
        human::seconds(sw.elapsed_s())
    );
    let top_url = store
        .read_assoc("deg_url")
        .unwrap();
    let mut best = (String::new(), 0.0);
    for (r, _, v) in top_url.iter() {
        let v = v.as_num().unwrap_or(0.0);
        if v > best.1 {
            best = (r.to_string(), v);
        }
    }
    println!("hottest url: {} with {} distinct clients", best.0, best.1);

    // BFS hops are one stacked multi-range scan each (the BatchScanner
    // idiom): the frontier becomes a coalesced range set the tablet
    // walk hops beneath the block copy. Hop 0 probes the seeds against
    // the table, so the bogus seed is dropped instead of reported as
    // reached.
    let seeds: Vec<String> =
        vec!["client00000".into(), "client00001".into(), "no-such-client".into()];
    let frontier = graphulo::bfs(&hits, &seeds, 1);
    println!(
        "bfs: {}/{} seeds exist in the table; 1-hop frontier reaches {} urls (one stacked \
         scan per hop)",
        frontier[0].len(),
        seeds.len(),
        frontier.get(1).map_or(0, |f| f.len()),
    );

    // ---- server-side iterator stack: filtered streaming scans -----------
    // A filtered scan runs *inside* the scan stack (Accumulo-style
    // iterator pushdown): the column window seeks past out-of-range
    // cells in the tablets, the glob filter drops non-matching cells
    // before they reach the client, and nothing materializes a full
    // Vec<Triple> — the stream is consumed one cell at a time.
    let sw = Stopwatch::start();
    let spec = ScanSpec::over(ScanRange::all().with_cols("/page000", "/page020"))
        .filtered(CellFilter::col(KeyMatch::Glob("/page00??".into())));
    let mut kept = 0usize;
    for t in hits.scan_stream(spec) {
        debug_assert!(t.col.starts_with("/page00"));
        kept += 1;
    }
    println!(
        "\nstreaming filtered scan: {kept} hits on /page00?? urls in {} (no materialization)",
        human::seconds(sw.elapsed_s())
    );
    // A multi-range stacked scan serves two disjoint url bands in one
    // pass over the transpose table (`ScanSpec::ranges`): the tablet
    // walk hops the gap between the bands beneath the block copy, so
    // the out-of-band urls are never copied.
    let sw = Stopwatch::start();
    let spec = ScanSpec::ranges([
        ScanRange::rows("/page000", "/page001"),
        ScanRange::rows("/page020", "/page021"),
    ]);
    let mut band_hits = 0usize;
    for t in hits_t.scan_stream(spec) {
        debug_assert!(t.row.starts_with("/page000") || t.row.starts_with("/page020"));
        band_hits += 1;
    }
    println!(
        "multi-range scan: {band_hits} hits across two url bands in {} (one stacked pass)",
        human::seconds(sw.elapsed_s())
    );
    // Seeded jaccard rides the same multi-range machinery: url↔url
    // co-visitor similarity restricted to a seed set of urls.
    let sw = Stopwatch::start();
    let url_seeds: Vec<String> = (0..10).map(|i| format!("/page{i:04}")).collect();
    let j = graphulo::jaccard_seeded(&hits_t, &url_seeds).expect("consistent jaccard triples");
    println!(
        "seeded jaccard: {} similar url pairs among {} seed urls in {}",
        j.nnz(),
        url_seeds.len(),
        human::seconds(sw.elapsed_s())
    );
    // A combiner stage collapses each row server-side: per-client hit
    // counts without shipping the hit cells at all.
    let sw = Stopwatch::start();
    let spec = ScanSpec::all().reduced(RowReduce::Count { out_col: "hits".into() });
    let mut busiest = (String::new(), 0u64);
    for t in hits.scan_stream(spec) {
        let n: u64 = t.val.parse().unwrap_or(0);
        if n > busiest.1 {
            busiest = (t.row.to_string(), n);
        }
    }
    println!(
        "combiner scan: busiest client {} with {} hits in {}",
        busiest.0,
        busiest.1,
        human::seconds(sw.elapsed_s())
    );

    // ---- masked TableMult: compute only the columns the sink keeps ------
    // TableMult(hits, hits) = AᵀA over urls; the sink mask restricts the
    // output columns to the /page00?? urls, so ~99% of the co-visitation
    // flops are never executed (masked SpGEMM under the hood).
    let cov_masked = store.create_table("covisit_page00x");
    let sw = Stopwatch::start();
    let cells = graphulo::table_mult_masked(
        &hits,
        &hits,
        &cov_masked,
        &PlusTimes,
        &KeyMatch::Glob("/page00??".into()),
    );
    println!(
        "masked TableMult: {cells} url co-visitation cells for /page00?? sinks in {}",
        human::seconds(sw.elapsed_s())
    );

    // ---- scan → Assoc → algebra -----------------------------------------
    let sw = Stopwatch::start();
    let a = hits.scan_to_assoc(ScanRange::all()); // client × url (1 = hit)
    println!(
        "\nscan→Assoc: {} in {}",
        a.summary(),
        human::seconds(sw.elapsed_s())
    );
    let per_client = a.count(1);
    let per_url = a.count(0);
    println!(
        "degrees via algebra: {} clients, {} urls (agrees with Graphulo: {})",
        per_client.nnz(),
        per_url.nnz(),
        per_client.nnz() == clients && per_url.nnz() == urls,
    );

    // url↔url co-visitation graph.
    let sw = Stopwatch::start();
    let covisit = a.sqin();
    println!("AᵀA co-visitation: {} in {}", covisit.summary(), human::seconds(sw.elapsed_s()));

    // ---- 4. PJRT-accelerated correlation --------------------------------
    match d4m::runtime::Runtime::load_default() {
        Ok(rt) => {
            let at = a.transpose();
            let sw = Stopwatch::start();
            let (accel, stats) = d4m::runtime::accel_matmul(&rt, &at, &a, &PlusTimes)
                .expect("accelerated matmul");
            let t_accel = sw.elapsed_s();
            let sw = Stopwatch::start();
            let host = at.matmul(&a);
            let t_host = sw.elapsed_s();
            println!(
                "\naccel AᵀA: PJRT {} ({} kernel calls, {} skipped, tile {}) vs host SpGEMM {} — equal: {}",
                human::seconds(t_accel),
                stats.kernel_calls,
                stats.skipped_tiles,
                stats.tile,
                human::seconds(t_host),
                accel == host,
            );
            assert_eq!(accel, host, "PJRT path must agree with host SpGEMM");
        }
        Err(e) => println!("\n(skipping PJRT stage: {e})"),
    }

    // ---- 5. the paper's five ops on real + reference data ---------------
    println!("\npaper-op timings on the ingested data + §III.A workload (n=12):");
    let w = Workload::generate(12, 42);
    let ones = w.ones();
    let wa = Assoc::from_triples(&w.rows, &w.cols, ValsInput::Num(ones.clone()));
    let wb = Assoc::from_triples(&w.rows2, &w.cols2, ValsInput::Num(ones));
    let reps = 5;
    let t1 = time_op(1, reps, |_| {
        Assoc::from_triples(&w.rows, &w.cols, ValsInput::Num(w.num_vals.clone()))
    });
    let t2 = time_op(1, reps, |_| {
        Assoc::try_new(
            w.rows.iter().map(|s| s.as_str().into()).collect(),
            w.cols.iter().map(|s| s.as_str().into()).collect(),
            ValsInput::Str(w.str_vals.clone()),
            Aggregator::Min,
        )
        .unwrap()
    });
    let t3 = time_op(1, reps, |_| wa.add(&wb));
    let t4 = time_op(1, reps, |_| wa.matmul(&wb));
    let t5 = time_op(1, reps, |_| wa.elemmul(&wb));
    for (name, t) in [
        ("constructor(num)", t1),
        ("constructor(str)", t2),
        ("add", t3),
        ("matmul", t4),
        ("elemmul", t5),
    ] {
        println!("  {name:18} mean {}", human::seconds(t.mean_s()));
    }
    println!("\ningest_pipeline OK");
}
