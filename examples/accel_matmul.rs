//! The dense-block PJRT acceleration path, standalone.
//!
//! Sweeps operand density and compares host SpGEMM vs the AOT-compiled
//! Pallas tile kernel (plus-times and min-plus), verifying exact
//! agreement and printing the crossover — the data behind the
//! `fig6b_accel` bench.
//!
//! Run: `cargo run --release --example accel_matmul`

use d4m::assoc::{Assoc, ValsInput};
use d4m::runtime::{accel_matmul, should_accelerate, Runtime};
use d4m::semiring::{MinPlus, PlusTimes, Semiring};
use d4m::util::{human, SplitMix64, Stopwatch};

fn random_assoc(seed: u64, keys: u64, density: f64) -> Assoc {
    let mut r = SplitMix64::new(seed);
    let triples = ((keys * keys) as f64 * density) as usize;
    let rows: Vec<String> = (0..triples).map(|_| format!("k{:05}", r.below(keys))).collect();
    let cols: Vec<String> = (0..triples).map(|_| format!("k{:05}", r.below(keys))).collect();
    let vals: Vec<f64> = (0..triples).map(|_| r.range_i64(1, 9) as f64).collect();
    Assoc::from_triples(&rows, &cols, ValsInput::Num(vals))
}

fn main() {
    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("loaded {} artifacts\n", rt.artifacts().count());

    for (sr, name) in [(&PlusTimes as &dyn Semiring, "plus_times"), (&MinPlus, "min_plus")] {
        println!("== semiring {name} ==");
        println!(
            "{:>9} {:>10} {:>12} {:>12} {:>8} {:>7} {:>6}",
            "density", "nnz", "host", "pjrt", "kcalls", "skip", "equal"
        );
        for density in [0.002, 0.01, 0.05, 0.2] {
            let a = random_assoc(1, 512, density);
            let b = random_assoc(2, 512, density);
            let sw = Stopwatch::start();
            let host = a.matmul_with(&b, sr);
            let t_host = sw.elapsed_s();
            let sw = Stopwatch::start();
            let (accel, stats) = accel_matmul(&rt, &a, &b, sr).expect("accel path");
            let t_accel = sw.elapsed_s();
            println!(
                "{:>9.3} {:>10} {:>12} {:>12} {:>8} {:>7} {:>6}",
                density,
                a.nnz(),
                human::seconds(t_host),
                human::seconds(t_accel),
                stats.kernel_calls,
                stats.skipped_tiles,
                accel == host,
            );
            assert_eq!(accel, host, "{name} PJRT result must equal host SpGEMM");
        }
        println!();
    }

    // The dispatch heuristic in action.
    let dense = random_assoc(3, 256, 0.3);
    let sparse = random_assoc(4, 4096, 0.0005);
    println!(
        "dispatch: dense {} → accelerate={}, sparse {} → accelerate={}",
        dense.summary(),
        should_accelerate(&dense, &dense, 0.02),
        sparse.summary(),
        should_accelerate(&sparse, &sparse, 0.02),
    );
    println!("accel_matmul OK");
}
