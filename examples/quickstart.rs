//! Quickstart: the paper's Figure 1 associative array, end to end.
//!
//! Builds the music-metadata array, exercises extraction (including the
//! D4M string-slice semantics), the algebra (`+`, `*`, `@`), and the
//! correlation idiom `AᵀA`.
//!
//! Run: `cargo run --release --example quickstart`

use d4m::assoc::{Assoc, Selector};

fn main() {
    // --- construction (paper Fig 1 / Fig 2) ---------------------------
    let a = Assoc::from_triples(
        &["0294.mp3", "0294.mp3", "0294.mp3", "1829.mp3", "1829.mp3", "1829.mp3", "7802.mp3",
            "7802.mp3", "7802.mp3"],
        &["artist", "duration", "genre", "artist", "duration", "genre", "artist", "duration",
            "genre"],
        &["Pink Floyd", "6:53", "rock", "Samuel Barber", "8:01", "classical", "Taylor Swift",
            "10:12", "pop"][..],
    );
    println!("A =\n{a}");

    // The four attributes of the storage model (paper §II.A).
    println!("A.row = {:?}", a.row_keys().iter().map(ToString::to_string).collect::<Vec<_>>());
    println!("A.col = {:?}", a.col_keys().iter().map(ToString::to_string).collect::<Vec<_>>());
    println!("A.val pool = {:?}", a.values().strings().unwrap());
    println!("A.adj nnz = {}\n", a.adj().nnz());

    // --- extraction (paper §II.B) --------------------------------------
    println!("one track:\n{}", a.get_row("0294.mp3"));
    // String slice "0294.mp3,:,1829.mp3," — inclusive on the right.
    let slice = a.select(&Selector::range("0294.mp3", "1829.mp3"), &Selector::All);
    println!("rows 0294..=1829 (right-inclusive!):\n{slice}");
    // Integers are positions, not keys (paper §II.B item 2).
    let by_pos = a.select(&Selector::PosRange(0, 2), &Selector::Positions(vec![0]));
    println!("A[0:2, [0]] by position:\n{by_pos}");

    // --- algebra (paper §II.C) ------------------------------------------
    let mask = Assoc::from_triples(&["0294.mp3", "7802.mp3"], &["genre", "genre"], 1.0);
    println!("string × numeric acts as a mask:\n{}", &a * &mask);

    let more = Assoc::from_triples(&["0294.mp3"], &["genre"], &["prog"][..]);
    println!("string + string concatenates on collision:\n{}", &a.get_col("genre") + &more);

    // AᵀA: which attributes co-occur across tracks (the facet idiom).
    println!("AᵀA =\n{}", a.sqin());

    // Degree-style reduction.
    println!("entries per track:\n{}", a.count(1));
    println!("quickstart OK");
}
