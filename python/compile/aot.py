"""AOT lowering: JAX -> HLO text artifacts for the Rust PJRT runtime.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's pinned xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py, whose recipe this follows).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per variant plus ``manifest.json``
describing shapes/semirings so the Rust side can discover and validate
artifacts without parsing HLO.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .kernels.semiring_matmul import vmem_bytes
from .model import accum_fn, matmul_fn

# (kind, semiring, size, block) — the artifact set the Rust runtime
# expects. 128 is the MXU-native tile; 256 amortizes dispatch for the
# plus-times path (4 MXU passes per grid step).
VARIANTS = [
    ("matmul", "plus_times", 128, 128),
    ("matmul", "plus_times", 256, 128),
    ("matmul", "max_plus", 128, 32),
    ("matmul", "min_plus", 128, 32),
    ("matmul", "max_min", 128, 32),
    ("accum", "plus_times", 128, 128),
    ("accum", "min_plus", 128, 32),
]


def to_hlo_text(lowered) -> str:
    """Lowered jax fn -> XLA HLO text (the 0.5.1-compatible bridge)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variant_name(kind: str, semiring: str, size: int) -> str:
    return f"{kind}_{semiring}_{size}"


def lower_variant(kind: str, semiring: str, size: int, block: int) -> str:
    if kind == "matmul":
        fn, specs = matmul_fn(semiring, size, block)
    elif kind == "accum":
        fn, specs = accum_fn(semiring, size, block)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    return to_hlo_text(fn.lower(*specs))


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for kind, semiring, size, block in VARIANTS:
        name = variant_name(kind, semiring, size)
        text = lower_variant(kind, semiring, size, block)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "kind": kind,
            "semiring": semiring,
            "size": size,
            "block": block,
            "dtype": "f32",
            "num_inputs": 3 if kind == "accum" else 2,
            "file": f"{name}.hlo.txt",
            "vmem_bytes_per_step": vmem_bytes(semiring, block, block, block),
        }
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # TSV twin for the Rust runtime (no JSON parser in its minimal
    # dependency set): name kind semiring size block num_inputs file.
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        for name in sorted(manifest):
            m = manifest[name]
            f.write(
                f"{name}\t{m['kind']}\t{m['semiring']}\t{m['size']}\t"
                f"{m['block']}\t{m['num_inputs']}\t{m['file']}\n"
            )
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    args = parser.parse_args()
    print(f"AOT-lowering {len(VARIANTS)} variants (jax {jax.__version__})")
    manifest = build_all(args.out_dir)
    print(f"wrote manifest with {len(manifest)} entries to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
