"""Pure-jnp oracle for the semiring matmul kernels.

Straight rank-3 broadcast + reduce (no blocking, no Pallas): the
definitionally-obvious implementation the kernels must agree with.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .semiring_matmul import SEMIRINGS


def semiring_matmul_ref(a: jax.Array, b: jax.Array, semiring: str = "plus_times") -> jax.Array:
    """``C[i,j] = ⊕_k A[i,k] ⊗ B[k,j]`` — unblocked reference."""
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}")
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if semiring == "plus_times":
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    _, _, mul = SEMIRINGS[semiring]
    expanded = mul(a[:, :, None], b[None, :, :])
    if semiring in ("max_plus", "max_min"):
        return jnp.max(expanded, axis=1)
    return jnp.min(expanded, axis=1)
