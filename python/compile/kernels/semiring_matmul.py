"""L1: blocked semiring matrix-multiply Pallas kernels.

The compute hot-spot of D4M is semiring matrix multiplication (`A @ B`,
Graphulo TableMult). The host-side Rust engine uses sparse SpGEMM; for
dense blocks the coordinator dispatches to these AOT-compiled kernels
instead (DESIGN.md §2 "Hardware-Adaptation").

TPU mapping (vs. the host sparse code, not a CUDA port — the paper has
no GPU design):

* tiles of ``(bm, bk) x (bk, bn)`` are staged HBM -> VMEM by ``BlockSpec``
  index maps over a ``(M/bm, N/bn, K/bk)`` grid;
* ``plus_times`` contracts tiles with ``jnp.dot`` -> MXU systolic array
  (f32 on CPU-interpret; bf16-accumulate-f32 on real TPU);
* the tropical algebras (``max_plus``/``min_plus``) and ``max_min``
  expand one rank and reduce -- VPU elementwise work, blocked so the
  ``(bm, bk, bn)`` intermediate stays VMEM-sized;
* the K grid dimension accumulates in the output ref (revisited across
  the innermost grid steps), initialized to the semiring zero at k == 0.

Kernels are lowered with ``interpret=True``: the CPU PJRT client cannot
execute Mosaic custom-calls, so interpret-mode lowering (plain HLO ops)
is the correctness + interchange path; real-TPU perf is *estimated* in
DESIGN.md from the VMEM footprint and MXU utilization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Semiring registry: name -> (zero, add, mul). `add` / `mul` operate on
# broadcastable jnp arrays.
SEMIRINGS = {
    "plus_times": (0.0, jnp.add, jnp.multiply),
    "max_plus": (-jnp.inf, jnp.maximum, jnp.add),
    "min_plus": (jnp.inf, jnp.minimum, jnp.add),
    "max_min": (-jnp.inf, jnp.maximum, jnp.minimum),
}


def _matmul_kernel(a_ref, b_ref, o_ref, *, semiring: str):
    """One (i, j, k) grid step: o[i,j] ⊕= a[i,k] ⊗. b[k,j]."""
    zero, add, _ = SEMIRINGS[semiring]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, zero)

    a = a_ref[...]
    b = b_ref[...]
    if semiring == "plus_times":
        # MXU path: a straight tile contraction.
        partial = jnp.dot(a, b, preferred_element_type=jnp.float32)
        o_ref[...] += partial
    else:
        _, _, mul = SEMIRINGS[semiring]
        # VPU path: rank-expand (bm, bk, bn) then ⊕-reduce over k.
        expanded = mul(a[:, :, None], b[None, :, :])
        if semiring in ("max_plus", "max_min"):
            partial = jnp.max(expanded, axis=1)
        else:
            partial = jnp.min(expanded, axis=1)
        o_ref[...] = add(o_ref[...], partial)


def semiring_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    semiring: str = "plus_times",
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked Pallas semiring matmul: ``C[i,j] = ⊕_k A[i,k] ⊗ B[k,j]``.

    Shapes must tile exactly: ``M % bm == K % bk == N % bn == 0`` (the
    Rust dispatcher pads blocks with the semiring zero, which is exactly
    the identity this kernel's ⊕-accumulation ignores).
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; have {sorted(SEMIRINGS)}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % bm or k % bk or n % bn:
        raise ValueError(f"shape {(m, k, n)} not tiled by blocks {(bm, bk, bn)}")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def vmem_bytes(semiring: str, bm: int, bk: int, bn: int) -> int:
    """Estimated VMEM working set of one grid step (f32), used by the
    DESIGN.md roofline estimate: A, B, O tiles (+ the rank-3 tropical
    intermediate)."""
    tiles = bm * bk + bk * bn + bm * bn
    if semiring != "plus_times":
        tiles += bm * bk * bn
    return 4 * tiles
