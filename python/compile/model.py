"""L2: the JAX compute graphs that get AOT-compiled for the Rust runtime.

Each exported function is a jitted graph over fixed shapes that calls
the L1 Pallas kernel, so the kernel lowers into the same HLO module the
Rust PJRT client loads. Python never runs at request time — these
graphs are lowered once by ``aot.py``.

Exported variants (see ``aot.VARIANTS``):

* ``matmul_<semiring>_<S>`` — S×S×S dense-block semiring matmul
  (the `@` acceleration path; the Rust side tiles larger operands over
  this fixed block and ⊕-combines partial blocks).
* ``accum_<semiring>_<S>`` — fused ``O = (A ⊗.⊕ B) ⊕ C``: one tile
  contraction *plus* the cross-tile accumulation, so the Rust tiling
  loop needs one PJRT call per k-step instead of a matmul call and a
  host-side combine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.semiring_matmul import SEMIRINGS, semiring_matmul


def matmul_fn(semiring: str, size: int, block: int):
    """A jitted ``(a, b) -> (c,)`` semiring matmul over ``size²`` tiles."""

    def fn(a, b):
        return (semiring_matmul(a, b, semiring=semiring, bm=block, bk=block, bn=block),)

    spec = jax.ShapeDtypeStruct((size, size), jnp.float32)
    return jax.jit(fn), (spec, spec)


def accum_fn(semiring: str, size: int, block: int):
    """A jitted ``(a, b, c) -> ((a ⊗.⊕ b) ⊕ c,)`` fused step."""
    _, add, _ = SEMIRINGS[semiring]

    def fn(a, b, c):
        partial = semiring_matmul(a, b, semiring=semiring, bm=block, bk=block, bn=block)
        return (add(partial, c),)

    spec = jax.ShapeDtypeStruct((size, size), jnp.float32)
    return jax.jit(fn), (spec, spec, spec)
