"""L1 correctness: the Pallas semiring-matmul kernel vs the pure-jnp
oracle — the core build-time correctness signal, swept by hypothesis
over shapes, blockings, dtypes and semirings."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import semiring_matmul_ref
from compile.kernels.semiring_matmul import SEMIRINGS, semiring_matmul, vmem_bytes

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, shape, dtype, semiring):
    if semiring == "plus_times":
        # Small integers: exact in f32, so equality is exact.
        return rng.integers(-4, 5, size=shape).astype(dtype)
    # Tropical algebras are exact for any float values.
    return (rng.standard_normal(shape) * 10).astype(dtype)


@pytest.mark.parametrize("semiring", sorted(SEMIRINGS))
def test_matches_ref_small(semiring):
    rng = np.random.default_rng(0)
    a = rand(rng, (16, 8), np.float32, semiring)
    b = rand(rng, (8, 24), np.float32, semiring)
    got = semiring_matmul(a, b, semiring=semiring, bm=8, bk=8, bn=8)
    want = semiring_matmul_ref(a, b, semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("semiring", sorted(SEMIRINGS))
@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 4),
    block=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**32 - 1),
)
def test_matches_ref_swept(semiring, mi, ki, ni, block, seed):
    """Random tiled shapes x blockings x seeds, exact agreement."""
    m, k, n = mi * block, ki * block, ni * block
    rng = np.random.default_rng(seed)
    a = rand(rng, (m, k), np.float32, semiring)
    b = rand(rng, (k, n), np.float32, semiring)
    got = semiring_matmul(a, b, semiring=semiring, bm=block, bk=block, bn=block)
    want = semiring_matmul_ref(a, b, semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    dtype=st.sampled_from([np.float32, np.float64, np.int32]),
    seed=st.integers(0, 2**32 - 1),
)
def test_dtype_coercion(dtype, seed):
    """Inputs of any numeric dtype are computed in f32."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-3, 4, size=(8, 8)).astype(dtype)
    b = rng.integers(-3, 4, size=(8, 8)).astype(dtype)
    got = semiring_matmul(a, b, semiring="plus_times", bm=8, bk=8, bn=8)
    assert got.dtype == jnp.float32
    want = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=0, atol=0)


def test_tropical_identity_padding_is_inert():
    """Padding with the semiring zero must not change results — the
    contract the Rust dispatcher's block-padding relies on."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((8, 8)).astype(np.float32)
    b = rng.standard_normal((8, 8)).astype(np.float32)
    want = semiring_matmul_ref(a, b, "min_plus")
    # Embed in a 16x16 problem padded with +inf (min_plus zero).
    pad_a = np.full((16, 16), np.inf, np.float32)
    pad_b = np.full((16, 16), np.inf, np.float32)
    pad_a[:8, :8] = a
    pad_b[:8, :8] = b
    got = semiring_matmul(pad_a, pad_b, semiring="min_plus", bm=8, bk=8, bn=8)
    np.testing.assert_allclose(np.asarray(got)[:8, :8], np.asarray(want), rtol=0, atol=0)


def test_plus_times_zero_padding_is_inert():
    rng = np.random.default_rng(8)
    a = rng.integers(-3, 4, size=(8, 8)).astype(np.float32)
    b = rng.integers(-3, 4, size=(8, 8)).astype(np.float32)
    want = np.asarray(a @ b)
    pad_a = np.zeros((16, 16), np.float32)
    pad_b = np.zeros((16, 16), np.float32)
    pad_a[:8, :8] = a
    pad_b[:8, :8] = b
    got = semiring_matmul(pad_a, pad_b, semiring="plus_times", bm=8, bk=8, bn=8)
    np.testing.assert_allclose(np.asarray(got)[:8, :8], want, rtol=0, atol=0)


def test_shape_validation():
    a = jnp.zeros((8, 8))
    with pytest.raises(ValueError, match="not tiled"):
        semiring_matmul(a, a, bm=3, bk=8, bn=8)
    with pytest.raises(ValueError, match="contraction mismatch"):
        semiring_matmul(jnp.zeros((8, 8)), jnp.zeros((4, 8)), bm=8, bk=8, bn=8)
    with pytest.raises(ValueError, match="unknown semiring"):
        semiring_matmul(a, a, semiring="nope")


def test_vmem_estimate_shapes():
    # plus_times: 3 tiles; tropical adds the rank-3 intermediate.
    assert vmem_bytes("plus_times", 128, 128, 128) == 4 * 3 * 128 * 128
    assert vmem_bytes("min_plus", 128, 32, 128) > vmem_bytes("plus_times", 128, 32, 128)
    # The chosen tropical blocking fits comfortably in 16 MiB VMEM.
    assert vmem_bytes("min_plus", 128, 32, 128) < 16 * 2**20
