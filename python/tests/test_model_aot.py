"""L2 + AOT: the exported graphs compute what they claim, and the
lowering pipeline emits loadable HLO text + a consistent manifest."""

import json

import numpy as np
import pytest

from compile import aot
from compile.kernels.ref import semiring_matmul_ref
from compile.model import accum_fn, matmul_fn


def test_matmul_fn_executes_and_matches_ref():
    fn, specs = matmul_fn("plus_times", 128, 128)
    rng = np.random.default_rng(0)
    a = rng.integers(-3, 4, size=specs[0].shape).astype(np.float32)
    b = rng.integers(-3, 4, size=specs[1].shape).astype(np.float32)
    (c,) = fn(a, b)
    np.testing.assert_allclose(
        np.asarray(c), np.asarray(semiring_matmul_ref(a, b, "plus_times")), rtol=0, atol=0
    )


def test_accum_fn_fuses_addition():
    fn, specs = accum_fn("min_plus", 128, 32)
    rng = np.random.default_rng(1)
    a = rng.standard_normal(specs[0].shape).astype(np.float32)
    b = rng.standard_normal(specs[1].shape).astype(np.float32)
    c = rng.standard_normal(specs[2].shape).astype(np.float32)
    (out,) = fn(a, b, c)
    want = np.minimum(np.asarray(semiring_matmul_ref(a, b, "min_plus")), c)
    np.testing.assert_allclose(np.asarray(out), want, rtol=0, atol=0)


def test_lower_variant_emits_hlo_text():
    text = aot.lower_variant("matmul", "plus_times", 128, 128)
    assert text.startswith("HloModule")
    assert "f32[128,128]" in text
    # return_tuple=True => tuple-shaped root.
    assert "(f32[128,128]" in text


@pytest.mark.parametrize("kind,semiring,size,block", aot.VARIANTS)
def test_all_variants_lower(kind, semiring, size, block):
    text = aot.lower_variant(kind, semiring, size, block)
    assert text.startswith("HloModule")
    assert f"f32[{size},{size}]" in text


def test_build_all_manifest(tmp_path):
    manifest = aot.build_all(str(tmp_path))
    assert len(manifest) == len(aot.VARIANTS)
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == manifest
    for name, meta in manifest.items():
        f = tmp_path / meta["file"]
        assert f.exists(), name
        assert f.read_text().startswith("HloModule")
        assert meta["num_inputs"] in (2, 3)
        assert meta["vmem_bytes_per_step"] < 16 * 2**20, "block must fit VMEM"
