#!/usr/bin/env python3
"""Render results/*.csv into the markdown tables EXPERIMENTS.md embeds.

Usage: python scripts/summarize_results.py [results_dir]
Prints one pivoted table (n x engine, mean seconds) per figure CSV.
"""

import csv
import os
import sys


def fmt(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}µs"


def pivot(path: str) -> str:
    rows = list(csv.DictReader(open(path)))
    if not rows:
        return f"(empty: {path})"
    engines = list(dict.fromkeys(r["engine"] for r in rows))
    ns = sorted({int(r["n"]) for r in rows})
    by = {(int(r["n"]), r["engine"]): float(r["mean_s"]) for r in rows}
    out = ["| n | " + " | ".join(engines) + " |",
           "|---|" + "|".join("---" for _ in engines) + "|"]
    for n in ns:
        cells = [fmt(by[(n, e)]) if (n, e) in by else "—" for e in engines]
        out.append(f"| {n} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results"
    for f in sorted(os.listdir(d)):
        if f.endswith(".csv"):
            print(f"### {f}\n")
            print(pivot(os.path.join(d, f)))
            print()


if __name__ == "__main__":
    main()
