#!/usr/bin/env python3
"""Render results/*.csv and results/BENCH_*.json into markdown tables.

Usage: python scripts/summarize_results.py [results_dir]
Prints one pivoted table (n x engine, mean seconds) per figure CSV, and
one record table per machine-readable bench JSON (schema d4m-bench-v1:
op, scale, threads, ns/op, speedup, plus optional extra metric fields —
e.g. the SpGEMM accumulator-policy row counters — rendered in a trailing
notes column).
"""

import csv
import json
import os
import sys


def fmt(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}µs"


def pivot(path: str) -> str:
    rows = list(csv.DictReader(open(path)))
    if not rows:
        return f"(empty: {path})"
    engines = list(dict.fromkeys(r["engine"] for r in rows))
    ns = sorted({int(r["n"]) for r in rows})
    by = {(int(r["n"]), r["engine"]): float(r["mean_s"]) for r in rows}
    out = ["| n | " + " | ".join(engines) + " |",
           "|---|" + "|".join("---" for _ in engines) + "|"]
    for n in ns:
        cells = [fmt(by[(n, e)]) if (n, e) in by else "—" for e in engines]
        out.append(f"| {n} | " + " | ".join(cells) + " |")
    return "\n".join(out)


CORE_FIELDS = ("op", "scale", "threads", "ns_per_op", "speedup")


def extras(record: dict) -> str:
    """Non-core fields (accumulator counters, cell counts, ...) as k=v."""
    parts = []
    for k, v in record.items():
        if k in CORE_FIELDS:
            continue
        if isinstance(v, float) and v == int(v):
            v = int(v)
        parts.append(f"{k}={v}")
    return " ".join(parts) or "—"


def bench_json(path: str) -> str:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "d4m-bench-v1":
        return f"(unknown schema in {path}: {doc.get('schema')!r})"
    records = doc.get("records", [])
    if not records:
        return f"(empty: {path})"
    has_extras = any(extras(r) != "—" for r in records)
    header = "| op | scale | threads | time/op | speedup |"
    sep = "|---|---|---|---|---|"
    if has_extras:
        header += " notes |"
        sep += "---|"
    out = [header, sep]
    for r in records:
        line = (
            f"| {r['op']} | {r['scale']} | {r['threads']} "
            f"| {fmt(r['ns_per_op'] * 1e-9)} | {r['speedup']:.2f}x |"
        )
        if has_extras:
            line += f" {extras(r)} |"
        out.append(line)
    return "\n".join(out)


# Acceptance-number ops: (op, human label, threshold asserted in-bench).
ACCEPTANCE = {
    "hypersparse-matmul-adaptive": ("adaptive vs dense hypersparse SpGEMM", 1.3),
    "tablemult-masked": ("masked vs unmasked TableMult", 1.5),
    "e2e-dict": ("dict-encoded vs string ctor+TableMult (end-to-end)", 1.3),
    "bfs-one-scan": ("one-scan BFS frontier vs per-node seeks", 1.4),
    "wal-recover": ("checkpoint recovery vs durable re-ingest", 5.0),
    "run-backed-scan": ("run-backed vs all-in-memory scan", 0.91),
    "wal-ingest-retry": ("durable ingest with retry layer vs no-retry", 0.95),
    "scan-under-writers": ("pinned-snapshot vs lock-per-block scan under writers", 1.3),
    "range-chunk-fanout": ("range-chunk vs per-tablet-group scan fan-out", 1.3),
    "block-cold-scan": ("capped block-cache cold scan vs resident (beyond-RAM)", 0.15),
    "block-warm-scan": ("warm block-cache scan vs resident", 0.91),
    "block-compact": ("streamed bounded-memory vs resident major compaction", 0.15),
    "plan-masked-mult": ("planner-chosen vs frozen-plan masked TableMult", 0.95),
    "plan-bfs": ("planner-chosen vs frozen-plan BFS", 0.95),
    "plan-adversarial-ingest": ("cost-rule vs frozen 8x ingest (adversarial)", 1.2),
}


def highlights(paths: list) -> str:
    """One line per acceptance-relevant record across the bench JSONs."""
    out = []
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if doc.get("schema") != "d4m-bench-v1":
            continue
        for r in doc.get("records", []):
            if r.get("op") in ACCEPTANCE:
                label, floor = ACCEPTANCE[r["op"]]
                mark = "ok" if r.get("speedup", 0.0) >= floor else "BELOW FLOOR"
                out.append(
                    f"- {label}: {r['speedup']:.2f}x "
                    f"(floor {floor}x, threads={r.get('threads')}, "
                    f"scale={r.get('scale')}) [{mark}]"
                )
    return "\n".join(out)


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results"
    json_paths = []
    for f in sorted(os.listdir(d)):
        path = os.path.join(d, f)
        if f.endswith(".csv"):
            print(f"### {f}\n")
            print(pivot(path))
            print()
        elif f.endswith(".json"):
            print(f"### {f}\n")
            print(bench_json(path))
            print()
            json_paths.append(path)
    hl = highlights(json_paths)
    if hl:
        print("### acceptance highlights\n")
        print(hl)
        print()


if __name__ == "__main__":
    main()
