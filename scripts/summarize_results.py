#!/usr/bin/env python3
"""Render results/*.csv and results/BENCH_*.json into markdown tables.

Usage: python scripts/summarize_results.py [results_dir]
Prints one pivoted table (n x engine, mean seconds) per figure CSV, and
one record table per machine-readable bench JSON (schema d4m-bench-v1:
op, scale, threads, ns/op, speedup).
"""

import csv
import json
import os
import sys


def fmt(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}µs"


def pivot(path: str) -> str:
    rows = list(csv.DictReader(open(path)))
    if not rows:
        return f"(empty: {path})"
    engines = list(dict.fromkeys(r["engine"] for r in rows))
    ns = sorted({int(r["n"]) for r in rows})
    by = {(int(r["n"]), r["engine"]): float(r["mean_s"]) for r in rows}
    out = ["| n | " + " | ".join(engines) + " |",
           "|---|" + "|".join("---" for _ in engines) + "|"]
    for n in ns:
        cells = [fmt(by[(n, e)]) if (n, e) in by else "—" for e in engines]
        out.append(f"| {n} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def bench_json(path: str) -> str:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "d4m-bench-v1":
        return f"(unknown schema in {path}: {doc.get('schema')!r})"
    records = doc.get("records", [])
    if not records:
        return f"(empty: {path})"
    out = ["| op | scale | threads | time/op | speedup |",
           "|---|---|---|---|---|"]
    for r in records:
        out.append(
            f"| {r['op']} | {r['scale']} | {r['threads']} "
            f"| {fmt(r['ns_per_op'] * 1e-9)} | {r['speedup']:.2f}x |"
        )
    return "\n".join(out)


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results"
    for f in sorted(os.listdir(d)):
        path = os.path.join(d, f)
        if f.endswith(".csv"):
            print(f"### {f}\n")
            print(pivot(path))
            print()
        elif f.endswith(".json"):
            print(f"### {f}\n")
            print(bench_json(path))
            print()


if __name__ == "__main__":
    main()
